//! PR 10 measurement plumbing: bandwidth-queueing links.
//!
//! This is the scenario behind `epiraft bench-pr10`, the committed
//! `BENCH_PR10.json`, and its `bench-smoke` gate. The grid is
//! {raft, v2, pull} × {unlimited, leader-uplink-capped} at n=101, all
//! cells sharing seed, rate and election timeouts:
//!
//! 1. **Unlimited** cells re-measure the latency-only model (and pin that
//!    the queueing counters stay exactly zero when `[sim.bandwidth]` has
//!    no rate for a link).
//! 2. **Capped** cells put a shared-NIC cap on replica 0's egress — sized
//!    from the unlimited runs (see [`derive_cap`]) to saturate classic
//!    Raft's per-request broadcast while leaving the epidemic variants
//!    ≥ 1.5× headroom — with a byte-bounded tail-drop queue.
//!
//! The gate then asserts the paper's claim under its most realistic
//! model: with the leader's uplink the bottleneck, classic must queue
//! behind its own fanout (wait > 0, drops > 0, commit p99 strictly above
//! its unlimited twin) while v2 and pull both commit with a strictly
//! lower p99 than capped classic. Safety everywhere, elections nowhere.

use super::figures::Scale;
use crate::config::{BandwidthLinkSpec, Config};
use crate::raft::Variant;
use crate::sim::{run_experiment, SimReport};
use crate::util::json::Json;

pub const UNLIMITED: &str = "unlimited";
pub const CAPPED: &str = "capped";

/// Queue depth as a fraction of the cap: `max_queue_bytes = cap / 50`,
/// i.e. at most ~20 ms of serialization backlog before tail-drop — deep
/// enough to show queueing delay, shallow enough that a saturated classic
/// leader must also drop (both effects are gated on).
pub const QUEUE_DEPTH_DIVISOR: u64 = 50;

/// One cell of the {variant} × {unlimited, capped} grid.
#[derive(Clone, Debug)]
pub struct QueueingPoint {
    pub variant: &'static str,
    /// [`UNLIMITED`] or [`CAPPED`].
    pub scenario: &'static str,
    /// The shared-NIC rate on replica 0 (bytes/s); 0 in unlimited cells.
    pub cap_bytes_per_sec: u64,
    pub completed: u64,
    pub throughput: f64,
    pub p99_latency_us: u64,
    /// Follower commit-interval p99 (leader append -> follower commit).
    pub commit_p99_us: u64,
    pub leader_egress_bytes: u64,
    pub queue_tail_drops: u64,
    pub peak_link_queue: u64,
    pub leader_queue_wait_us: u64,
    pub elections: u64,
    pub safety_ok: bool,
}

impl QueueingPoint {
    fn from_report(scenario: &'static str, cap: u64, r: &SimReport) -> Self {
        Self {
            variant: r.variant,
            scenario,
            cap_bytes_per_sec: cap,
            completed: r.completed,
            throughput: r.throughput,
            p99_latency_us: r.p99_latency_us,
            commit_p99_us: r.commit_interval.p99(),
            leader_egress_bytes: r.leader_egress_bytes,
            queue_tail_drops: r.queue_tail_drops,
            peak_link_queue: r.peak_link_queue,
            leader_queue_wait_us: r.leader_queue_wait_us,
            elections: r.elections,
            safety_ok: r.safety_ok,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("variant", Json::str(self.variant)),
            ("scenario", Json::str(self.scenario)),
            ("cap_bytes_per_sec", Json::num(self.cap_bytes_per_sec as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("throughput", Json::num(self.throughput)),
            ("p99_latency_us", Json::num(self.p99_latency_us as f64)),
            ("commit_p99_us", Json::num(self.commit_p99_us as f64)),
            ("leader_egress_bytes", Json::num(self.leader_egress_bytes as f64)),
            ("queue_tail_drops", Json::num(self.queue_tail_drops as f64)),
            ("peak_link_queue", Json::num(self.peak_link_queue as f64)),
            ("leader_queue_wait_us", Json::num(self.leader_queue_wait_us as f64)),
            ("elections", Json::num(self.elections as f64)),
            ("safety_ok", Json::Bool(self.safety_ok)),
        ])
    }
}

/// Build one cell's config. `cap = 0` is the unlimited scenario; a
/// positive cap puts a shared-NIC bandwidth bottleneck (egress + ingress)
/// on replica 0 — the bootstrap leader — with a byte-bounded queue.
fn cell_cfg(scale: Scale, variant: Variant, cap: u64, rate: f64, seed: u64) -> Config {
    let mut cfg = Config {
        protocol: crate::config::ProtocolConfig::for_variant(scale.n, variant),
        ..Config::default()
    };
    // Same election timeouts in every cell, far past any queueing delay a
    // saturated uplink can add: a capped leader's heartbeats arrive late
    // by design, and this is a queueing measurement, not a failover
    // benchmark (the bench-pr4 precedent for slow-but-alive replicas).
    cfg.protocol.election_timeout_min_us = 30_000_000;
    cfg.protocol.election_timeout_max_us = 60_000_000;
    cfg.workload.clients = 10;
    cfg.workload.rate = rate;
    cfg.workload.duration_us = scale.duration_us;
    cfg.workload.warmup_us = scale.warmup_us;
    cfg.seed = seed;
    if cap > 0 {
        cfg.network.bandwidth.links.push(BandwidthLinkSpec { selector: "0".into(), rate: cap });
        // Bound the queue in bytes, not frames: frame sizes differ per
        // variant, and ~20 ms of backlog is the same physical statement
        // for all of them.
        cfg.network.bandwidth.max_queue = 0;
        cfg.network.bandwidth.max_queue_bytes = (cap / QUEUE_DEPTH_DIVISOR).max(1);
    }
    cfg
}

/// Size the leader-uplink cap from the *measured* unlimited runs: 60% of
/// classic Raft's observed leader-egress rate (so its broadcast demand
/// exceeds the NIC by ~1.67× and must queue), but never below 1.5× the
/// epidemic variants' observed rates (so v2/pull keep real headroom and
/// the comparison isolates classic's fanout, not a starved cluster).
/// Deriving instead of hardcoding keeps the cap meaningful whatever the
/// scale, rate or payload sizes of the run.
pub fn derive_cap(unlimited: &[QueueingPoint], duration_us: u64) -> Result<u64, String> {
    let secs = duration_us as f64 / 1e6;
    let rate_of = |name: &str| -> Result<f64, String> {
        unlimited
            .iter()
            .find(|p| p.variant == name && p.scenario == UNLIMITED)
            .map(|p| p.leader_egress_bytes as f64 / secs)
            .ok_or_else(|| format!("derive_cap: unlimited '{name}' cell missing"))
    };
    let raft = rate_of(Variant::Raft.name())?;
    let v2 = rate_of(Variant::V2.name())?;
    let pull = rate_of(Variant::Pull.name())?;
    let cap = (0.6 * raft).max(1.5 * v2.max(pull));
    if cap < 1.0 {
        return Err("derive_cap: unlimited cells moved no leader bytes".into());
    }
    Ok(cap as u64)
}

/// Run the grid: three unlimited cells, derive the cap, then the same
/// three variants behind it — same n/seed/rate, the cells differ only in
/// `[sim.bandwidth]`.
pub fn queueing_comparison(scale: Scale, rate: f64, seed: u64) -> Vec<QueueingPoint> {
    let variants = [Variant::Raft, Variant::V2, Variant::Pull];
    let mut out = Vec::new();
    for &variant in &variants {
        let cfg = cell_cfg(scale, variant, 0, rate, seed);
        out.push(QueueingPoint::from_report(UNLIMITED, 0, &run_experiment(&cfg)));
    }
    let cap = derive_cap(&out, scale.duration_us).expect("unlimited cells just ran");
    for &variant in &variants {
        let cfg = cell_cfg(scale, variant, cap, rate, seed);
        out.push(QueueingPoint::from_report(CAPPED, cap, &run_experiment(&cfg)));
    }
    out
}

fn find<'a>(
    points: &'a [QueueingPoint],
    variant: &str,
    scenario: &str,
) -> Result<&'a QueueingPoint, String> {
    points
        .iter()
        .find(|p| p.variant == variant && p.scenario == scenario)
        .ok_or_else(|| format!("gate: cell {variant}/{scenario} missing from results"))
}

/// The CI gate (`epiraft bench-pr10` exit status):
///
/// * every cell is safe, leader-stable, serving, with a sane commit p99;
/// * unlimited cells report exactly zero queueing activity (the
///   default-off pin, at bench scale);
/// * capped classic demonstrably queued behind its own fanout: wait > 0,
///   tail-drops > 0, commit p99 strictly above its unlimited twin;
/// * both epidemic variants beat capped classic on commit p99 under the
///   same uplink cap — the paper's claim as a *timing* win.
pub fn queueing_gate(points: &[QueueingPoint]) -> Result<(), String> {
    for p in points {
        if !p.safety_ok {
            return Err(format!("gate: safety violated in {}/{}", p.variant, p.scenario));
        }
        if p.elections > 0 {
            return Err(format!(
                "gate: leader deposed ({} election(s)) in {}/{}",
                p.elections, p.variant, p.scenario
            ));
        }
        if p.completed == 0 {
            return Err(format!("gate: {}/{} served no requests", p.variant, p.scenario));
        }
        if p.commit_p99_us == 0 || p.commit_p99_us > 30_000_000 {
            return Err(format!(
                "gate: {}/{} commit p99 {}us is not sane",
                p.variant, p.scenario, p.commit_p99_us
            ));
        }
        if p.scenario == UNLIMITED
            && (p.queue_tail_drops != 0 || p.peak_link_queue != 0 || p.leader_queue_wait_us != 0)
        {
            return Err(format!(
                "gate: unlimited '{}' cell reported queueing activity (drops {}, peak {}, \
                 wait {}us) — the default-off pin is broken",
                p.variant, p.queue_tail_drops, p.peak_link_queue, p.leader_queue_wait_us
            ));
        }
    }
    let raft_free = find(points, Variant::Raft.name(), UNLIMITED)?;
    let raft_cap = find(points, Variant::Raft.name(), CAPPED)?;
    let v2_cap = find(points, Variant::V2.name(), CAPPED)?;
    let pull_cap = find(points, Variant::Pull.name(), CAPPED)?;
    if raft_cap.leader_queue_wait_us == 0 {
        return Err("gate: capped classic shows no queue wait — the cap did not bind".into());
    }
    if raft_cap.queue_tail_drops == 0 {
        return Err("gate: capped classic never overflowed its bounded queue".into());
    }
    if raft_cap.commit_p99_us <= raft_free.commit_p99_us {
        return Err(format!(
            "gate: capped classic commit p99 {}us not above its unlimited twin's {}us",
            raft_cap.commit_p99_us, raft_free.commit_p99_us
        ));
    }
    for epi in [v2_cap, pull_cap] {
        if epi.commit_p99_us >= raft_cap.commit_p99_us {
            return Err(format!(
                "gate: capped '{}' commit p99 {}us not strictly below capped classic's {}us",
                epi.variant, epi.commit_p99_us, raft_cap.commit_p99_us
            ));
        }
    }
    Ok(())
}

/// Render the whole scenario as the `BENCH_PR10.json` document.
pub fn bench_pr10_json(scale: Scale, rate: f64, seed: u64, points: &[QueueingPoint]) -> Json {
    let gate = queueing_gate(points);
    let cap = points
        .iter()
        .find(|p| p.scenario == CAPPED)
        .map_or(0, |p| p.cap_bytes_per_sec);
    Json::obj(vec![
        ("bench", Json::str("bandwidth-queueing")),
        ("n", Json::num(scale.n as f64)),
        ("rate", Json::num(rate)),
        ("duration_us", Json::num(scale.duration_us as f64)),
        ("warmup_us", Json::num(scale.warmup_us as f64)),
        ("seed", Json::num(seed as f64)),
        ("cap_bytes_per_sec", Json::num(cap as f64)),
        ("points", Json::arr(points.iter().map(|p| p.to_json()))),
        ("gate_queueing", Json::Bool(gate.is_ok())),
        (
            "gate_detail",
            match gate {
                Ok(()) => Json::str(
                    "all cells safe and leader-stable; unlimited cells queue-free; capped \
                     classic queued and dropped behind its own fanout; v2 and pull beat it \
                     on commit p99 under the same uplink cap",
                ),
                Err(e) => Json::str(&e),
            },
        ),
    ])
}

/// Print the grid.
pub fn print_queueing(points: &[QueueingPoint]) {
    println!("\n== bandwidth-queueing links: {{raft, v2, pull}} x {{unlimited, capped}} ==");
    println!(
        "{:<8} {:<10} {:>12} {:>10} {:>14} {:>12} {:>10} {:>12}",
        "variant",
        "scenario",
        "cap_B/s",
        "completed",
        "commit_p99_us",
        "wait_us",
        "drops",
        "peak_q"
    );
    for p in points {
        println!(
            "{:<8} {:<10} {:>12} {:>10} {:>14} {:>12} {:>10} {:>12}",
            p.variant,
            p.scenario,
            p.cap_bytes_per_sec,
            p.completed,
            p.commit_p99_us,
            p.leader_queue_wait_us,
            p.queue_tail_drops,
            p.peak_link_queue
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Tiny scale: the grid's mechanics (cap derivation, gate wiring, JSON
    // shape) are testable without n=101; `bench-pr10` itself runs the real
    // size in the bench-smoke CI job.
    fn tiny() -> Scale {
        Scale { reps: 1, duration_us: 1_500_000, warmup_us: 300_000, n: 15 }
    }

    #[test]
    fn gate_passes_at_tiny_scale_and_rejects_tampering() {
        let points = queueing_comparison(tiny(), 300.0, 7);
        assert_eq!(points.len(), 6);
        queueing_gate(&points).expect("tiny-scale gate");
        // Tamper 1: an unlimited cell claims queueing activity.
        let mut bad = points.clone();
        bad[0].queue_tail_drops = 1;
        assert!(queueing_gate(&bad).is_err(), "default-off pin must be enforced");
        // Tamper 2: capped classic claims a free ride through the cap.
        let mut bad = points.clone();
        for p in bad.iter_mut() {
            if p.variant == Variant::Raft.name() && p.scenario == CAPPED {
                p.leader_queue_wait_us = 0;
            }
        }
        assert!(queueing_gate(&bad).is_err(), "the cap must demonstrably bind");
        // Tamper 3: pretend classic out-committed the epidemic variants.
        let mut bad = points.clone();
        for p in bad.iter_mut() {
            if p.variant == Variant::Raft.name() && p.scenario == CAPPED {
                p.commit_p99_us = 1;
            }
        }
        assert!(queueing_gate(&bad).is_err(), "the timing win must be real");
        // Tamper 4: a safety violation anywhere fails the gate.
        let mut bad = points.clone();
        bad[5].safety_ok = false;
        assert!(queueing_gate(&bad).is_err());
    }

    #[test]
    fn derived_cap_binds_classic_and_spares_the_epidemic_variants() {
        let points = queueing_comparison(tiny(), 300.0, 7);
        let secs = tiny().duration_us as f64 / 1e6;
        let cap = points.iter().find(|p| p.scenario == CAPPED).unwrap().cap_bytes_per_sec;
        let rate_of = |name: &str| {
            points
                .iter()
                .find(|p| p.variant == name && p.scenario == UNLIMITED)
                .unwrap()
                .leader_egress_bytes as f64
                / secs
        };
        assert!((cap as f64) < rate_of(Variant::Raft.name()), "cap must undercut classic");
        assert!((cap as f64) >= 1.5 * rate_of(Variant::V2.name()), "v2 must keep headroom");
        assert!((cap as f64) >= 1.5 * rate_of(Variant::Pull.name()), "pull must keep headroom");
    }

    #[test]
    fn bench_json_has_cells_and_gate() {
        let points = queueing_comparison(tiny(), 300.0, 7);
        let j = bench_pr10_json(tiny(), 300.0, 7, &points);
        assert_eq!(j.get("points").and_then(|v| v.as_arr()).unwrap().len(), 6);
        assert!(j.get("gate_queueing").and_then(|g| g.as_bool()).is_some());
        assert!(j.get("cap_bytes_per_sec").is_some());
        let text = j.to_string_pretty();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.get("bench").and_then(|b| b.as_str()), Some("bandwidth-queueing"));
    }
}
