//! PR 6 measurement plumbing: open-loop throughput with and without
//! leader group-commit batching, in the simulator at n=51 and on a
//! loopback-TCP live cluster.
//!
//! This is the scenario behind `epiraft bench-pr6`, the committed
//! `BENCH_PR6.json`, and CI's `bench-smoke` gate for the group-commit
//! path (`raft::node::flush_batch`): under one open-loop Poisson workload
//! per (host, variant) pair — cells inside a pair differ *only* in
//! `protocol.batch.enabled` — the batched cell must complete strictly
//! more requests while its client p99 stays within 1.5x of the unbatched
//! cell's. The win comes from two different places, and the cell shapes
//! are chosen so each one is the binding constraint:
//!
//! * **classic Raft** — the unbatched leader pays per-command broadcast
//!   and per-ack receive costs (`n-1` sends + `n-1` receives per
//!   command), which caps it far below the offered rate at n=51; group
//!   commit amortizes that fan-out over the whole flushed batch. The pair
//!   runs deliberately overloaded, so the admission cap sheds the excess
//!   and `completed` measures sustainable throughput.
//! * **pull** — the leader is cheap either way (acks are per-round), so
//!   the pair instead runs with a *small* inflight cap and a seed-round
//!   interval well above the flush interval: unbatched commands wait for
//!   the next scheduled round (`on_client_request` clamps it to
//!   `round_interval_us` out), batched commands ride the flush
//!   (`on_batch_flush` fires the round immediately). Little's law turns
//!   the latency gap into throughput through the fixed slot count.
//!
//! The classic sim cells raise the election timeout: a saturated leader
//! queues up to `max_inflight x per-command cost` (~160ms at n=51) of
//! work ahead of its heartbeat tick, and the comparison is about
//! throughput, not leader stability under overload.

use super::figures::Scale;
use crate::cluster::{run_live, LiveReport};
use crate::config::{ArrivalModel, Config, KeyDist};
use crate::raft::Variant;
use crate::sim::{run_experiment, SimReport};
use crate::util::json::Json;

const SIM: &str = "sim";
const TCP: &str = "tcp";
const BATCHED: &str = "batched";
const UNBATCHED: &str = "unbatched";

/// One (host, variant, mode) cell of the comparison grid.
#[derive(Clone, Debug)]
pub struct ThroughputPoint {
    /// `"sim"` (discrete-event, n=51) or `"tcp"` (loopback live cluster).
    pub host: &'static str,
    pub variant: &'static str,
    /// `"unbatched"` or `"batched"` (`protocol.batch.enabled`).
    pub mode: &'static str,
    pub completed: u64,
    pub throughput: f64,
    pub mean_latency_us: f64,
    pub p99_latency_us: u64,
    /// Open-loop arrivals shed at admission (the overload relief valve).
    pub shed: u64,
    /// Sim cells only; 0 on tcp (the live report has no election count).
    pub elections: u64,
    pub max_commit: u64,
    /// `safety_ok` (sim) / `logs_consistent` (tcp).
    pub safe: bool,
}

impl ThroughputPoint {
    fn from_sim(mode: &'static str, r: &SimReport) -> ThroughputPoint {
        ThroughputPoint {
            host: SIM,
            variant: r.variant,
            mode,
            completed: r.completed,
            throughput: r.throughput,
            mean_latency_us: r.mean_latency_us,
            p99_latency_us: r.p99_latency_us,
            shed: r.shed,
            elections: r.elections,
            max_commit: r.max_commit,
            safe: r.safety_ok,
        }
    }

    fn from_live(mode: &'static str, r: &LiveReport) -> ThroughputPoint {
        ThroughputPoint {
            host: TCP,
            variant: r.variant,
            mode,
            completed: r.completed,
            throughput: r.throughput,
            mean_latency_us: r.mean_latency_us,
            p99_latency_us: r.p99_latency_us,
            shed: r.shed,
            elections: 0,
            max_commit: r.commit_index.iter().copied().max().unwrap_or(0),
            safe: r.logs_consistent,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("host", Json::str(self.host)),
            ("variant", Json::str(self.variant)),
            ("mode", Json::str(self.mode)),
            ("completed", Json::num(self.completed as f64)),
            ("throughput", Json::num(self.throughput)),
            ("mean_latency_us", Json::num(self.mean_latency_us)),
            ("p99_latency_us", Json::num(self.p99_latency_us as f64)),
            ("shed", Json::num(self.shed as f64)),
            ("elections", Json::num(self.elections as f64)),
            ("max_commit", Json::num(self.max_commit as f64)),
            ("safe", Json::Bool(self.safe)),
        ])
    }
}

/// Variants in the grid: the two the ISSUE gates (classic push fan-out
/// vs round-paced pull).
fn grid_variants() -> [Variant; 2] {
    [Variant::Raft, Variant::Pull]
}

fn mode_name(batched: bool) -> &'static str {
    if batched {
        BATCHED
    } else {
        UNBATCHED
    }
}

/// Shared cell shape: open-loop zipfian workload, batch knobs set in both
/// cells of a pair so `batch.enabled` is the *only* difference.
fn open_loop_cfg(n: usize, variant: Variant, batched: bool, seed: u64) -> Config {
    let mut cfg = Config {
        protocol: crate::config::ProtocolConfig::for_variant(n, variant),
        ..Config::default()
    };
    cfg.workload.arrival = ArrivalModel::Open;
    cfg.workload.key_dist = KeyDist::Zipfian;
    cfg.workload.zipf_theta = 0.99;
    cfg.protocol.batch.enabled = batched;
    cfg.seed = seed;
    cfg
}

fn sim_cell(scale: Scale, variant: Variant, batched: bool, seed: u64) -> Config {
    let mut cfg = open_loop_cfg(scale.n, variant, batched, seed);
    cfg.workload.duration_us = scale.duration_us;
    cfg.workload.warmup_us = scale.warmup_us;
    match variant {
        Variant::Pull => {
            // Latency-shaped pair: the slot cap binds, not the leader CPU.
            cfg.workload.rate = 2_000.0;
            cfg.workload.max_inflight = 4;
            cfg.protocol.batch.flush_us = 2_000;
            cfg.protocol.batch.max_entries = 64;
            cfg.protocol.round_interval_us = 15_000;
            cfg.protocol.pull_interval_us = 2_000;
        }
        _ => {
            // CPU-shaped pair: deliberately overloaded so the unbatched
            // leader's per-command fan-out cost is the binding constraint.
            cfg.workload.rate = 2_000.0;
            cfg.workload.max_inflight = 32;
            cfg.protocol.batch.flush_us = 20_000;
            cfg.protocol.batch.max_entries = 64;
            // Saturation queueing delay must stay inside the election
            // timeout (see module docs).
            cfg.protocol.election_timeout_min_us = 500_000;
            cfg.protocol.election_timeout_max_us = 1_000_000;
        }
    }
    cfg
}

fn tcp_cell(scale: Scale, tcp_n: usize, variant: Variant, batched: bool, seed: u64) -> Config {
    let mut cfg = open_loop_cfg(tcp_n, variant, batched, seed);
    // Wall-clock cells: bound each run so the full 4-cell TCP sweep stays
    // CI-sized even at paper scale.
    cfg.workload.duration_us = scale.duration_us.min(3_000_000);
    cfg.workload.warmup_us = scale.warmup_us.min(cfg.workload.duration_us / 5);
    cfg.set("cluster.transport", "tcp").expect("tcp transport knob");
    match variant {
        Variant::Pull => {
            // Interval-dominated: latency tracks the configured round /
            // flush cadence, not host speed — robust across CI runners.
            cfg.workload.rate = 50_000.0;
            cfg.workload.max_inflight = 16;
            cfg.protocol.batch.flush_us = 1_000;
            cfg.protocol.batch.max_entries = 256;
            cfg.protocol.round_interval_us = 15_000;
            cfg.protocol.pull_interval_us = 2_000;
        }
        _ => {
            // Always-overloaded: shedding absorbs machine-speed variance,
            // `completed` measures per-command vs per-flush leader cost.
            cfg.workload.rate = 500_000.0;
            cfg.workload.max_inflight = 256;
            cfg.protocol.batch.flush_us = 300;
            cfg.protocol.batch.max_entries = 256;
        }
    }
    cfg
}

/// The deterministic half of the grid: {raft, pull} x {unbatched,
/// batched} in the simulator. Tier-1 tests gate on this half only — the
/// TCP half is wall-clock and belongs to CI's `bench-smoke`.
pub fn sim_throughput_comparison(scale: Scale, seed: u64) -> Vec<ThroughputPoint> {
    let mut out = Vec::new();
    for variant in grid_variants() {
        for batched in [false, true] {
            let cfg = sim_cell(scale, variant, batched, seed);
            out.push(ThroughputPoint::from_sim(mode_name(batched), &run_experiment(&cfg)));
        }
    }
    out
}

/// The full grid: the sim half plus the same pairs on a loopback-TCP
/// live cluster of `tcp_n` replicas.
pub fn throughput_comparison(
    scale: Scale,
    tcp_n: usize,
    seed: u64,
) -> Result<Vec<ThroughputPoint>, String> {
    let mut out = sim_throughput_comparison(scale, seed);
    for variant in grid_variants() {
        for batched in [false, true] {
            let cfg = tcp_cell(scale, tcp_n, variant, batched, seed);
            out.push(ThroughputPoint::from_live(mode_name(batched), &run_live(&cfg)?));
        }
    }
    Ok(out)
}

fn find<'a>(
    points: &'a [ThroughputPoint],
    host: &str,
    variant: &str,
    mode: &str,
) -> Result<&'a ThroughputPoint, String> {
    points
        .iter()
        .find(|p| p.host == host && p.variant == variant && p.mode == mode)
        .ok_or_else(|| format!("gate: cell {host}/{variant}/{mode} missing from results"))
}

/// The CI gate (`epiraft bench-pr6` exit status):
///
/// * every measured cell is safe (cross-replica prefix agreement) and
///   completed something;
/// * sim cells kept their leader (the comparison is not about elections);
/// * for every (host, variant) pair present, the batched cell completed
///   strictly more requests than the unbatched cell under the identical
///   open-loop offered rate, at a client p99 within 1.5x.
pub fn throughput_gate(points: &[ThroughputPoint]) -> Result<(), String> {
    if points.is_empty() {
        return Err("gate: no cells measured".into());
    }
    for p in points {
        if !p.safe {
            return Err(format!(
                "gate: safety violated in the {}/{}/{} run",
                p.host, p.variant, p.mode
            ));
        }
        if p.completed == 0 {
            return Err(format!(
                "gate: nothing completed in the {}/{}/{} run",
                p.host, p.variant, p.mode
            ));
        }
        if p.host == SIM && p.elections > 0 {
            return Err(format!(
                "gate: leader deposed ({} election(s)) in the sim {}/{} run",
                p.elections, p.variant, p.mode
            ));
        }
    }
    let mut pairs: Vec<(&str, &str)> = Vec::new();
    for p in points {
        if !pairs.contains(&(p.host, p.variant)) {
            pairs.push((p.host, p.variant));
        }
    }
    for (host, variant) in pairs {
        let un = find(points, host, variant, UNBATCHED)?;
        let ba = find(points, host, variant, BATCHED)?;
        if ba.completed <= un.completed {
            return Err(format!(
                "gate: {host}/{variant} batched completed {} is not strictly above unbatched's {}",
                ba.completed, un.completed
            ));
        }
        if un.p99_latency_us == 0 {
            return Err(format!(
                "gate: {host}/{variant} unbatched baseline recorded no latency",
            ));
        }
        if ba.p99_latency_us as f64 > un.p99_latency_us as f64 * 1.5 {
            return Err(format!(
                "gate: {host}/{variant} batched p99 {}us exceeds 1.5x unbatched's {}us",
                ba.p99_latency_us, un.p99_latency_us
            ));
        }
    }
    Ok(())
}

/// Render the whole scenario (config + grid + gate verdict) as the
/// `BENCH_PR6.json` document.
pub fn bench_pr6_json(scale: Scale, tcp_n: usize, seed: u64, points: &[ThroughputPoint]) -> Json {
    let gate = throughput_gate(points);
    Json::obj(vec![
        ("bench", Json::str("open-loop-group-commit")),
        ("n", Json::num(scale.n as f64)),
        ("tcp_n", Json::num(tcp_n as f64)),
        ("duration_us", Json::num(scale.duration_us as f64)),
        ("warmup_us", Json::num(scale.warmup_us as f64)),
        ("seed", Json::num(seed as f64)),
        ("points", Json::arr(points.iter().map(|p| p.to_json()))),
        ("gate_batched_beats_unbatched", Json::Bool(gate.is_ok())),
        (
            "gate_detail",
            match gate {
                Ok(()) => Json::str(
                    "batched cells complete strictly more at p99 within 1.5x, per (host, variant) pair",
                ),
                Err(e) => Json::str(&e),
            },
        ),
    ])
}

/// Print the comparison table.
pub fn print_throughput(points: &[ThroughputPoint]) {
    println!("\n== open-loop throughput: group commit vs per-command (same offered rate) ==");
    println!(
        "{:<4} {:<6} {:<10} {:>10} {:>12} {:>10} {:>10} {:>8}",
        "host", "var", "mode", "completed", "tput(req/s)", "p99(us)", "shed", "safety"
    );
    for p in points {
        println!(
            "{:<4} {:<6} {:<10} {:>10} {:>12.1} {:>10} {:>10} {:>8}",
            p.host,
            p.variant,
            p.mode,
            p.completed,
            p.throughput,
            p.p99_latency_us,
            p.shed,
            if p.safe { "OK" } else { "VIOLATED" }
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Scale {
        Scale { reps: 1, duration_us: 1_500_000, warmup_us: 300_000, n: 7 }
    }

    #[test]
    fn comparison_covers_the_sim_grid() {
        let pts = sim_throughput_comparison(tiny(), 11);
        assert_eq!(pts.len(), 4, "2 variants x 2 modes");
        for p in &pts {
            assert_eq!(p.host, "sim");
            assert!(p.safe, "{}/{}", p.variant, p.mode);
            assert!(p.completed > 0, "{}/{}", p.variant, p.mode);
            assert!(p.max_commit > 0, "{}/{}", p.variant, p.mode);
        }
        for variant in ["raft", "pull"] {
            for mode in ["unbatched", "batched"] {
                find(&pts, "sim", variant, mode).expect("cell present");
            }
        }
        // The classic pair runs overloaded by construction: the open-loop
        // engine must shed at the admission cap rather than queue without
        // bound.
        let un = find(&pts, "sim", "raft", "unbatched").unwrap();
        assert!(un.shed > 0, "overloaded unbatched raft cell never shed");
    }

    #[test]
    fn gate_passes_at_moderate_scale_and_rejects_tampering() {
        // n=15 rather than the tiny n=7: the unbatched classic leader's
        // per-command fan-out cost needs a few peers before it clearly
        // binds below the batched cell's client-path cost. CI runs the
        // claim at n=51.
        let scale = Scale { reps: 1, duration_us: 1_500_000, warmup_us: 300_000, n: 15 };
        let pts = sim_throughput_comparison(scale, 11);
        throughput_gate(&pts).expect("batched must beat unbatched in both sim pairs");
        let mut bad = pts.clone();
        for p in bad.iter_mut() {
            if p.mode == "batched" {
                p.completed = 0;
            }
        }
        assert!(throughput_gate(&bad).is_err(), "zeroed batched cells must fail the gate");
        let mut bad = pts.clone();
        for p in bad.iter_mut() {
            if p.variant == "pull" && p.mode == "batched" {
                p.p99_latency_us = u64::MAX;
            }
        }
        assert!(throughput_gate(&bad).is_err(), "blown batched p99 must fail the gate");
    }

    #[test]
    fn gate_requires_both_modes_of_a_pair() {
        let pts = sim_throughput_comparison(tiny(), 11);
        let only_batched: Vec<_> =
            pts.iter().filter(|p| p.mode == "batched").cloned().collect();
        assert!(
            throughput_gate(&only_batched).is_err(),
            "a pair missing its baseline must not pass"
        );
    }

    #[test]
    fn bench_json_round_trips_with_gate_fields() {
        let pts = sim_throughput_comparison(tiny(), 11);
        let j = bench_pr6_json(tiny(), 5, 11, &pts);
        assert_eq!(j.get("points").and_then(|v| v.as_arr()).unwrap().len(), 4);
        assert!(j.get("gate_batched_beats_unbatched").and_then(|g| g.as_bool()).is_some());
        let parsed = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(
            parsed.get("bench").and_then(|b| b.as_str()),
            Some("open-loop-group-commit")
        );
    }
}
