//! Experiment harness: regenerates every figure of the paper's evaluation
//! (Fig 4–7), the §6 headline numbers, and the ablations, plus the
//! criterion-lite bench-stats used by `cargo bench`.

pub mod ablation;
pub mod adaptive;
pub mod bench_stats;
pub mod egress;
pub mod figures;
pub mod queueing;
pub mod recovery;
pub mod scale;
pub mod soak;
pub mod throughput;
pub mod unreliable;

pub use adaptive::{
    adaptive_comparison, adaptive_gate, bench_pr3_json, print_adaptive, AdaptivePoint,
};
pub use bench_stats::{bench, black_box, BenchResult};
pub use egress::{
    bench_pr2_json, egress_gate, leader_egress_comparison, print_egress, EgressPoint,
};
pub use figures::{
    fig4, fig4_default_rates, fig5, fig5_default_rates, fig6, fig6_default_ns, fig7, headline,
    print_points, run_point, write_cdfs_json, write_points_json, Headline, Point, Scale,
};
pub use queueing::{
    bench_pr10_json, print_queueing, queueing_comparison, queueing_gate, QueueingPoint,
};
pub use recovery::{
    bench_pr7_json, print_recovery, recovery_comparison, recovery_gate, RecoveryPoint,
};
pub use scale::{
    bench_pr8_json, compact_comparison, fleet_scale, print_scale, protocol_metrics, scale_gate,
    CompactPoint, FleetCell, ProtocolPoint,
};
pub use soak::{
    bench_pr9_json, print_soak, sim_soak_comparison, soak_comparison, soak_gate, SoakPoint,
    SIM_LIVE_TOLERANCE,
};
pub use throughput::{
    bench_pr6_json, print_throughput, sim_throughput_comparison, throughput_comparison,
    throughput_gate, ThroughputPoint,
};
pub use unreliable::{
    bench_pr4_json, print_unreliable, unreliable_comparison, unreliable_gate, UnreliablePoint,
};
