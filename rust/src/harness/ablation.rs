//! Ablation studies for the design choices called out in DESIGN.md §4:
//! gossip fanout / round period sensitivity (A1), V2 success-responses and
//! classic-Raft coalescing window (A2).

use super::figures::{run_point, Point, Scale};
use crate::config::presets;
use crate::raft::Variant;

/// A1a — fanout sweep for V1 and V2 at fixed load.
pub fn ablate_fanout(scale: Scale, fanouts: &[usize], rate: f64) -> Vec<Point> {
    let mut out = Vec::new();
    for variant in [Variant::V1, Variant::V2] {
        for &f in fanouts {
            let mut cfg = presets::fig4(variant, rate);
            cfg.protocol.n = scale.n;
            cfg.protocol.fanout = f;
            cfg.workload.duration_us = scale.duration_us;
            cfg.workload.warmup_us = scale.warmup_us;
            out.push(run_point(variant.name(), f as f64, &cfg, scale.reps));
        }
    }
    out
}

/// A1b — round-period sweep (latency/CPU trade-off of gossip cadence).
pub fn ablate_round_interval(scale: Scale, intervals_us: &[u64], rate: f64) -> Vec<Point> {
    let mut out = Vec::new();
    for variant in [Variant::V1, Variant::V2] {
        for &iv in intervals_us {
            let mut cfg = presets::fig4(variant, rate);
            cfg.protocol.n = scale.n;
            cfg.protocol.round_interval_us = iv;
            cfg.workload.duration_us = scale.duration_us;
            cfg.workload.warmup_us = scale.warmup_us;
            out.push(run_point(variant.name(), iv as f64, &cfg, scale.reps));
        }
    }
    out
}

/// A2a — V2 with and without first-receipt success responses
/// (DESIGN.md §4.3). Returns (off, on).
pub fn ablate_v2_responses(scale: Scale, rate: f64) -> (Point, Point) {
    let mut base = presets::fig4(Variant::V2, rate);
    base.protocol.n = scale.n;
    base.workload.duration_us = scale.duration_us;
    base.workload.warmup_us = scale.warmup_us;
    let off = run_point("v2-silent", 0.0, &base, scale.reps);
    let mut on_cfg = base.clone();
    on_cfg.protocol.v2_success_responses = true;
    let on = run_point("v2-ack", 1.0, &on_cfg, scale.reps);
    (off, on)
}

/// A2b — classic Raft with a coalescing window (does batching alone close
/// the gap to V1?).
pub fn ablate_raft_coalesce(scale: Scale, windows_us: &[u64], rate: f64) -> Vec<Point> {
    let mut out = Vec::new();
    for &w in windows_us {
        let mut cfg = presets::fig4(Variant::Raft, rate);
        cfg.protocol.n = scale.n;
        cfg.protocol.raft_coalesce_us = w;
        cfg.workload.duration_us = scale.duration_us;
        cfg.workload.warmup_us = scale.warmup_us;
        out.push(run_point("raft", w as f64, &cfg, scale.reps));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Scale {
        Scale { reps: 1, duration_us: 1_200_000, warmup_us: 300_000, n: 5 }
    }

    #[test]
    fn fanout_sweep_runs() {
        let pts = ablate_fanout(tiny(), &[1, 3], 300.0);
        assert_eq!(pts.len(), 4);
        assert!(pts.iter().all(|p| p.throughput > 0.0));
    }

    #[test]
    fn v2_response_ablation_increases_leader_load() {
        let (off, on) = ablate_v2_responses(
            Scale { reps: 1, duration_us: 2_000_000, warmup_us: 400_000, n: 9 },
            400.0,
        );
        // With success responses on, every follower answers every round —
        // the leader must do at least as much work.
        assert!(on.leader_cpu >= off.leader_cpu * 0.9, "on={} off={}", on.leader_cpu, off.leader_cpu);
    }

    #[test]
    fn coalesce_sweep_runs() {
        let pts = ablate_raft_coalesce(tiny(), &[0, 5_000], 300.0);
        assert_eq!(pts.len(), 2);
    }
}
