//! PR 9 measurement plumbing: the telemetry soak and the sim-vs-live
//! cross-check behind `epiraft bench-pr9`, the committed
//! `BENCH_PR9.json`, and CI's `bench-smoke` gate.
//!
//! The paper's central claim is a statement about the *leader's share*
//! of replication egress: classic Raft concentrates it, the epidemic
//! pull variant spreads it across peers. Until now that claim was
//! checked per host in isolation — sim totals in PR 2, live TCP totals
//! in PR 5/6. This scenario samples both hosts *over time* through the
//! shared telemetry series (`telemetry::S_*`, DESIGN.md §10) and gates
//! on two things at once:
//!
//! * **ordering, per host** — the pull variant's leader-egress share
//!   `leader / (leader + peers)` is strictly below classic Raft's, in
//!   the simulator (n = `Scale::n`) *and* on loopback TCP (n =
//!   `tcp_n`);
//! * **agreement, across hosts** — classic Raft's *live* leader share
//!   agrees with the simulator's prediction at the same n within
//!   [`SIM_LIVE_TOLERANCE`]. Both hosts meter replica-to-replica bytes
//!   with the same size model (`Message::wire_bytes` in the sim, the
//!   codec's actual framed bytes on TCP), so the share — a ratio, which
//!   cancels rate and duration — is the honest point of contact.
//!
//! Every cell runs the PR 6 open-loop workload with telemetry sampling
//! on, and the gate also insists the sampled series behave: ≥ 2 frames
//! per cell and a monotone leader-egress counter across them.

use super::figures::Scale;
use crate::cluster::{run_live, LiveReport};
use crate::config::{ArrivalModel, Config};
use crate::raft::Variant;
use crate::sim::{run_experiment, SimReport};
use crate::telemetry::{Frame, S_LEADER_EGRESS};
use crate::util::json::Json;

const SIM: &str = "sim";
const TCP: &str = "tcp";

/// How far the live classic-Raft leader share may sit from the
/// simulator's prediction at the same n (absolute share, i.e. 15
/// percentage points). The sim prices messages with `Message::
/// wire_bytes`; the live cluster counts the codec's real framed bytes —
/// the model tracks the codec closely, but reconnect retransmits and
/// repair traffic land only on one side, hence the headroom.
pub const SIM_LIVE_TOLERANCE: f64 = 0.15;

/// One (host, variant, n) cell of the soak grid.
#[derive(Clone, Debug)]
pub struct SoakPoint {
    /// `"sim"` (discrete-event) or `"tcp"` (loopback live cluster).
    pub host: &'static str,
    pub variant: &'static str,
    pub n: usize,
    pub completed: u64,
    pub shed: u64,
    pub leader_egress_bytes: u64,
    pub peer_egress_bytes_total: u64,
    /// `leader / (leader + peers)` — the quantity the paper is about.
    pub leader_share: f64,
    /// Telemetry frames sampled over the run.
    pub frames: u64,
    /// The sampled leader-egress series never decreased.
    pub egress_monotone: bool,
    /// Sim cells only; 0 on tcp.
    pub elections: u64,
    pub max_commit: u64,
    /// `safety_ok` (sim) / `logs_consistent` (tcp).
    pub safe: bool,
}

fn share(leader: u64, peers: u64) -> f64 {
    let total = leader + peers;
    if total == 0 {
        0.0
    } else {
        leader as f64 / total as f64
    }
}

/// True when the sampled leader-egress series never decreases. Vacuously
/// true for empty samples — the gate checks frame counts separately.
fn monotone_leader_egress(samples: &[Frame]) -> bool {
    let mut last = f64::MIN;
    for f in samples {
        let Some(v) = f.get(S_LEADER_EGRESS) else { return false };
        if v < last {
            return false;
        }
        last = v;
    }
    true
}

impl SoakPoint {
    fn from_sim(r: &SimReport) -> SoakPoint {
        SoakPoint {
            host: SIM,
            variant: r.variant,
            n: r.n,
            completed: r.completed,
            shed: r.shed,
            leader_egress_bytes: r.leader_egress_bytes,
            peer_egress_bytes_total: r.peer_egress_bytes_total,
            leader_share: share(r.leader_egress_bytes, r.peer_egress_bytes_total),
            frames: r.samples.len() as u64,
            egress_monotone: monotone_leader_egress(&r.samples),
            elections: r.elections,
            max_commit: r.max_commit,
            safe: r.safety_ok,
        }
    }

    fn from_live(r: &LiveReport) -> SoakPoint {
        SoakPoint {
            host: TCP,
            variant: r.variant,
            n: r.n,
            completed: r.completed,
            shed: r.shed,
            leader_egress_bytes: r.leader_egress_bytes,
            peer_egress_bytes_total: r.peer_egress_bytes_total,
            leader_share: share(r.leader_egress_bytes, r.peer_egress_bytes_total),
            frames: r.samples.len() as u64,
            egress_monotone: monotone_leader_egress(&r.samples),
            elections: 0,
            max_commit: r.commit_index.iter().copied().max().unwrap_or(0),
            safe: r.logs_consistent,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("host", Json::str(self.host)),
            ("variant", Json::str(self.variant)),
            ("n", Json::num(self.n as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("shed", Json::num(self.shed as f64)),
            ("leader_egress_bytes", Json::num(self.leader_egress_bytes as f64)),
            ("peer_egress_bytes_total", Json::num(self.peer_egress_bytes_total as f64)),
            ("leader_share", Json::num(self.leader_share)),
            ("frames", Json::num(self.frames as f64)),
            ("egress_monotone", Json::Bool(self.egress_monotone)),
            ("elections", Json::num(self.elections as f64)),
            ("max_commit", Json::num(self.max_commit as f64)),
            ("safe", Json::Bool(self.safe)),
        ])
    }
}

/// The two variants the claim compares: classic push fan-out vs the
/// epidemic pull mesh (PR 2's pair, now sampled over time).
fn grid_variants() -> [Variant; 2] {
    [Variant::Raft, Variant::Pull]
}

/// Shared cell shape: the PR 6 open-loop workload at a rate every cell
/// can sustain (the claim is about egress *shares*, not capacity), with
/// telemetry sampling on at a tenth of the run.
fn soak_cfg(n: usize, variant: Variant, duration_us: u64, warmup_us: u64, seed: u64) -> Config {
    let mut cfg = Config {
        protocol: crate::config::ProtocolConfig::for_variant(n, variant),
        ..Config::default()
    };
    cfg.workload.arrival = ArrivalModel::Open;
    cfg.workload.rate = 300.0;
    cfg.workload.max_inflight = 16;
    cfg.workload.duration_us = duration_us;
    cfg.workload.warmup_us = warmup_us;
    cfg.telemetry.interval_us = (duration_us / 10).max(50_000);
    // The comparison is about egress attribution, not leader stability:
    // keep the leader seated even if a large-n classic cell queues work
    // ahead of its heartbeat (same reasoning as the PR 6 cells).
    cfg.protocol.election_timeout_min_us = cfg.protocol.election_timeout_min_us.max(500_000);
    cfg.protocol.election_timeout_max_us = cfg.protocol.election_timeout_max_us.max(1_000_000);
    cfg.seed = seed;
    cfg
}

/// The deterministic half of the grid: {raft, pull} in the simulator at
/// `scale.n`, plus — when it differs — the same pair at `tcp_n`, the
/// prediction the live cells are checked against. Tier-1 tests gate on
/// this half; the TCP half is wall-clock and belongs to CI.
pub fn sim_soak_comparison(scale: Scale, tcp_n: usize, seed: u64) -> Vec<SoakPoint> {
    let mut out = Vec::new();
    let mut ns = vec![scale.n];
    if tcp_n != scale.n {
        ns.push(tcp_n);
    }
    for n in ns {
        for variant in grid_variants() {
            let cfg = soak_cfg(n, variant, scale.duration_us, scale.warmup_us, seed);
            out.push(SoakPoint::from_sim(&run_experiment(&cfg)));
        }
    }
    out
}

/// The full grid: the sim half plus {raft, pull} on a loopback-TCP live
/// cluster of `tcp_n` replicas, sampled by the live `Sampler`.
pub fn soak_comparison(scale: Scale, tcp_n: usize, seed: u64) -> Result<Vec<SoakPoint>, String> {
    let mut out = sim_soak_comparison(scale, tcp_n, seed);
    for variant in grid_variants() {
        let duration = scale.duration_us.min(3_000_000);
        let warmup = scale.warmup_us.min(duration / 5);
        let mut cfg = soak_cfg(tcp_n, variant, duration, warmup, seed);
        cfg.telemetry.interval_us = 100_000;
        cfg.set("cluster.transport", "tcp").expect("tcp transport knob");
        out.push(SoakPoint::from_live(&run_live(&cfg)?));
    }
    Ok(out)
}

fn find<'a>(
    points: &'a [SoakPoint],
    host: &str,
    variant: &str,
    n: usize,
) -> Result<&'a SoakPoint, String> {
    points
        .iter()
        .find(|p| p.host == host && p.variant == variant && p.n == n)
        .ok_or_else(|| format!("gate: cell {host}/{variant}/n={n} missing from results"))
}

/// The CI gate (`epiraft bench-pr9` exit status):
///
/// * every cell is safe, completed something, sampled ≥ 2 telemetry
///   frames with a monotone leader-egress series, and split its egress
///   meaningfully (leader and peers both nonzero);
/// * sim cells kept their leader;
/// * per (host, n) group: the pull cell's leader-egress share is
///   *strictly* below classic Raft's;
/// * for every tcp group, a sim group at the same n exists and classic
///   Raft's live share sits within [`SIM_LIVE_TOLERANCE`] of the sim
///   prediction.
pub fn soak_gate(points: &[SoakPoint]) -> Result<(), String> {
    if points.is_empty() {
        return Err("gate: no cells measured".into());
    }
    for p in points {
        let cell = format!("{}/{}/n={}", p.host, p.variant, p.n);
        if !p.safe {
            return Err(format!("gate: safety violated in the {cell} run"));
        }
        if p.completed == 0 {
            return Err(format!("gate: nothing completed in the {cell} run"));
        }
        if p.host == SIM && p.elections > 0 {
            return Err(format!(
                "gate: leader deposed ({} election(s)) in the {cell} run",
                p.elections
            ));
        }
        if p.frames < 2 {
            return Err(format!(
                "gate: only {} telemetry frame(s) sampled in the {cell} run",
                p.frames
            ));
        }
        if !p.egress_monotone {
            return Err(format!(
                "gate: sampled leader-egress series not monotone in the {cell} run"
            ));
        }
        if p.leader_egress_bytes == 0 || p.peer_egress_bytes_total == 0 {
            return Err(format!(
                "gate: degenerate egress split ({} leader / {} peers) in the {cell} run",
                p.leader_egress_bytes, p.peer_egress_bytes_total
            ));
        }
    }
    let mut groups: Vec<(&str, usize)> = Vec::new();
    for p in points {
        if !groups.contains(&(p.host, p.n)) {
            groups.push((p.host, p.n));
        }
    }
    for &(host, n) in &groups {
        let raft = find(points, host, "raft", n)?;
        let pull = find(points, host, "pull", n)?;
        if pull.leader_share >= raft.leader_share {
            return Err(format!(
                "gate: {host}/n={n} pull leader share {:.3} is not strictly below classic's {:.3}",
                pull.leader_share, raft.leader_share
            ));
        }
    }
    for &(host, n) in &groups {
        if host != TCP {
            continue;
        }
        let live = find(points, TCP, "raft", n)?;
        let sim = find(points, SIM, "raft", n).map_err(|_| {
            format!("gate: tcp group n={n} has no sim prediction cell to cross-check against")
        })?;
        let delta = (live.leader_share - sim.leader_share).abs();
        if delta > SIM_LIVE_TOLERANCE {
            return Err(format!(
                "gate: classic leader share disagrees across hosts at n={n}: \
                 live {:.3} vs sim {:.3} (|Δ| {:.3} > {SIM_LIVE_TOLERANCE})",
                live.leader_share, sim.leader_share, delta
            ));
        }
    }
    Ok(())
}

/// Render the whole scenario (config + grid + gate verdict) as the
/// `BENCH_PR9.json` document.
pub fn bench_pr9_json(scale: Scale, tcp_n: usize, seed: u64, points: &[SoakPoint]) -> Json {
    let gate = soak_gate(points);
    Json::obj(vec![
        ("bench", Json::str("telemetry-soak-cross-check")),
        ("n", Json::num(scale.n as f64)),
        ("tcp_n", Json::num(tcp_n as f64)),
        ("duration_us", Json::num(scale.duration_us as f64)),
        ("warmup_us", Json::num(scale.warmup_us as f64)),
        ("seed", Json::num(seed as f64)),
        ("sim_live_tolerance", Json::num(SIM_LIVE_TOLERANCE)),
        ("points", Json::arr(points.iter().map(|p| p.to_json()))),
        ("gate_leader_share", Json::Bool(gate.is_ok())),
        (
            "gate_detail",
            match gate {
                Ok(()) => Json::str(
                    "pull leader-egress share strictly below classic's per (host, n); \
                     live classic share within tolerance of the sim prediction",
                ),
                Err(e) => Json::str(&e),
            },
        ),
    ])
}

/// Print the comparison table.
pub fn print_soak(points: &[SoakPoint]) {
    println!("\n== telemetry soak: leader egress share, sim vs live (same series) ==");
    println!(
        "{:<4} {:<6} {:>4} {:>10} {:>14} {:>14} {:>7} {:>7} {:>8}",
        "host", "var", "n", "completed", "leader(B)", "peers(B)", "share", "frames", "safety"
    );
    for p in points {
        println!(
            "{:<4} {:<6} {:>4} {:>10} {:>14} {:>14} {:>7.3} {:>7} {:>8}",
            p.host,
            p.variant,
            p.n,
            p.completed,
            p.leader_egress_bytes,
            p.peer_egress_bytes_total,
            p.leader_share,
            p.frames,
            if p.safe { "OK" } else { "VIOLATED" }
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Scale {
        Scale { reps: 1, duration_us: 1_500_000, warmup_us: 300_000, n: 15 }
    }

    #[test]
    fn sim_comparison_covers_both_ns_and_samples_frames() {
        let pts = sim_soak_comparison(tiny(), 5, 11);
        assert_eq!(pts.len(), 4, "2 ns x 2 variants");
        for p in &pts {
            assert_eq!(p.host, "sim");
            assert!(p.safe, "{}/n={}", p.variant, p.n);
            assert!(p.completed > 0, "{}/n={}", p.variant, p.n);
            assert!(p.frames >= 2, "{}/n={}: {} frames", p.variant, p.n, p.frames);
            assert!(p.egress_monotone, "{}/n={}", p.variant, p.n);
            assert!(p.leader_share > 0.0 && p.leader_share < 1.0);
        }
        for n in [15, 5] {
            for variant in ["raft", "pull"] {
                find(&pts, "sim", variant, n).expect("cell present");
            }
        }
        // Same scale.n and tcp_n: no duplicate cells.
        assert_eq!(sim_soak_comparison(tiny(), 15, 11).len(), 2);
    }

    #[test]
    fn gate_passes_on_the_sim_grid_and_rejects_tampering() {
        let pts = sim_soak_comparison(tiny(), 5, 11);
        soak_gate(&pts).expect("pull share must undercut classic in both sim groups");
        // Swap the shares: ordering must fail.
        let mut bad = pts.clone();
        for p in bad.iter_mut() {
            if p.variant == "pull" {
                p.leader_share = 0.99;
            }
        }
        assert!(soak_gate(&bad).is_err(), "inverted shares must fail the gate");
        // Strip the samples: the soak is about time series, not totals.
        let mut bad = pts.clone();
        bad[0].frames = 0;
        assert!(soak_gate(&bad).is_err(), "a frameless cell must fail the gate");
        let mut bad = pts.clone();
        bad[1].egress_monotone = false;
        assert!(soak_gate(&bad).is_err(), "a non-monotone series must fail the gate");
        // A tcp cell with no sim prediction at its n must fail loudly.
        let mut orphan = pts.clone();
        let mut fake = pts[0].clone();
        fake.host = "tcp";
        fake.n = 3;
        let mut fake_pull = fake.clone();
        fake_pull.variant = "pull";
        fake_pull.leader_share = 0.1;
        orphan.push(fake);
        orphan.push(fake_pull);
        assert!(soak_gate(&orphan).is_err(), "unpredicted tcp group must fail");
    }

    #[test]
    fn gate_cross_checks_live_against_sim_within_tolerance() {
        let pts = sim_soak_comparison(tiny(), 5, 11);
        // Synthesize the live cells from the sim prediction: within
        // tolerance passes, outside fails.
        let mk_live = |delta: f64| -> Vec<SoakPoint> {
            let mut all = pts.clone();
            for variant in ["raft", "pull"] {
                let sim = find(&pts, "sim", variant, 5).unwrap();
                let mut live = sim.clone();
                live.host = "tcp";
                live.leader_share = (sim.leader_share + delta).clamp(0.001, 0.999);
                all.push(live);
            }
            all
        };
        soak_gate(&mk_live(0.05)).expect("agreeing live cells must pass");
        assert!(
            soak_gate(&mk_live(SIM_LIVE_TOLERANCE + 0.05)).is_err(),
            "a live share outside tolerance must fail"
        );
    }

    #[test]
    fn bench_json_round_trips_with_gate_fields() {
        let pts = sim_soak_comparison(tiny(), 5, 11);
        let j = bench_pr9_json(tiny(), 5, 11, &pts);
        assert_eq!(j.get("points").and_then(|v| v.as_arr()).unwrap().len(), 4);
        assert!(j.get("gate_leader_share").and_then(|g| g.as_bool()).is_some());
        let parsed = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(
            parsed.get("bench").and_then(|b| b.as_str()),
            Some("telemetry-soak-cross-check")
        );
        assert_eq!(
            parsed.get("sim_live_tolerance").and_then(Json::as_f64),
            Some(SIM_LIVE_TOLERANCE)
        );
    }
}
