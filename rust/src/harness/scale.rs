//! PR 8 measurement plumbing: the event-driven simulator core at scale.
//!
//! This is the scenario behind `epiraft bench-pr8`, the committed
//! `BENCH_PR8.json`, and CI's `scale-smoke` gate. Three cells:
//!
//! 1. **Compact payloads** (n=501, V2): the same run with
//!    `protocol.compact_payloads` off vs on must complete identically —
//!    the encoding is wire-only — while every egress meter shrinks.
//! 2. **Protocol metrics** (n=2001): raft / v2 / pull each safe and
//!    leader-stable at four-digit n, with classic Raft's leader egress
//!    strictly above both epidemic variants' (the paper's scaling claim,
//!    two orders of magnitude past its n=51 testbed).
//! 3. **Fleet** (n=10 000): the sharded native engine bit-identical to
//!    the single-thread run, converging well under the round cap.
//!
//! Wall-clock per cell is *recorded* (events, heap traffic, host µs per
//! simulated second) but never gated on — the gates are deterministic.

use super::figures::Scale;
use crate::config::Config;
use crate::raft::Variant;
use crate::sim::{converge, converge_sharded, run_experiment, Backend, ConvergenceReport, SimReport};
use crate::util::json::Json;

/// Fleet cell geometry: the n=10k convergence point and its sharding.
pub const FLEET_N: usize = 10_000;
pub const FLEET_FANOUT: usize = 8;
pub const FLEET_SHARDS: usize = 8;

/// One run of the compact-payload cell (V2, same seed, knob off vs on).
#[derive(Clone, Debug)]
pub struct CompactPoint {
    /// "dense" (knob off) or "compact" (knob on).
    pub mode: &'static str,
    pub completed: u64,
    pub messages: u64,
    pub mean_latency_us: f64,
    pub leader_egress_bytes: u64,
    pub peer_egress_bytes_total: u64,
    pub safety_ok: bool,
    pub elections: u64,
    pub events_processed: u64,
    pub heap_pushes: u64,
    pub peak_queue_depth: u64,
    pub host_us_per_sim_sec: f64,
}

impl CompactPoint {
    fn from_report(mode: &'static str, r: &SimReport) -> Self {
        Self {
            mode,
            completed: r.completed,
            messages: r.messages,
            mean_latency_us: r.mean_latency_us,
            leader_egress_bytes: r.leader_egress_bytes,
            peer_egress_bytes_total: r.peer_egress_bytes_total,
            safety_ok: r.safety_ok,
            elections: r.elections,
            events_processed: r.events_processed,
            heap_pushes: r.heap_pushes,
            peak_queue_depth: r.peak_queue_depth,
            host_us_per_sim_sec: r.host_us_per_sim_sec,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("mode", Json::str(self.mode)),
            ("completed", Json::num(self.completed as f64)),
            ("messages", Json::num(self.messages as f64)),
            ("mean_latency_us", Json::num(self.mean_latency_us)),
            ("leader_egress_bytes", Json::num(self.leader_egress_bytes as f64)),
            (
                "peer_egress_bytes_total",
                Json::num(self.peer_egress_bytes_total as f64),
            ),
            ("safety_ok", Json::Bool(self.safety_ok)),
            ("elections", Json::num(self.elections as f64)),
            ("events_processed", Json::num(self.events_processed as f64)),
            ("heap_pushes", Json::num(self.heap_pushes as f64)),
            ("peak_queue_depth", Json::num(self.peak_queue_depth as f64)),
            ("host_us_per_sim_sec", Json::num(self.host_us_per_sim_sec)),
        ])
    }
}

/// One variant's run in the n=2001 protocol-metrics cell.
#[derive(Clone, Debug)]
pub struct ProtocolPoint {
    pub variant: &'static str,
    pub completed: u64,
    pub throughput: f64,
    pub p99_latency_us: u64,
    /// Follower commit-interval p99 (leader append -> follower commit).
    pub commit_p99_us: u64,
    pub leader_egress_bytes: u64,
    pub safety_ok: bool,
    pub elections: u64,
    pub events_processed: u64,
    pub peak_queue_depth: u64,
    pub host_us_per_sim_sec: f64,
}

impl ProtocolPoint {
    fn from_report(r: &SimReport) -> Self {
        Self {
            variant: r.variant,
            completed: r.completed,
            throughput: r.throughput,
            p99_latency_us: r.p99_latency_us,
            commit_p99_us: r.commit_interval.p99(),
            leader_egress_bytes: r.leader_egress_bytes,
            safety_ok: r.safety_ok,
            elections: r.elections,
            events_processed: r.events_processed,
            peak_queue_depth: r.peak_queue_depth,
            host_us_per_sim_sec: r.host_us_per_sim_sec,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("variant", Json::str(self.variant)),
            ("completed", Json::num(self.completed as f64)),
            ("throughput", Json::num(self.throughput)),
            ("p99_latency_us", Json::num(self.p99_latency_us as f64)),
            ("commit_p99_us", Json::num(self.commit_p99_us as f64)),
            ("leader_egress_bytes", Json::num(self.leader_egress_bytes as f64)),
            ("safety_ok", Json::Bool(self.safety_ok)),
            ("elections", Json::num(self.elections as f64)),
            ("events_processed", Json::num(self.events_processed as f64)),
            ("peak_queue_depth", Json::num(self.peak_queue_depth as f64)),
            ("host_us_per_sim_sec", Json::num(self.host_us_per_sim_sec)),
        ])
    }
}

/// The fleet cell: single-thread and sharded runs of the same seed.
#[derive(Clone, Debug)]
pub struct FleetCell {
    pub single: ConvergenceReport,
    pub sharded: ConvergenceReport,
}

fn cell_config(scale: Scale, variant: Variant, rate: f64, seed: u64) -> Config {
    let mut cfg = Config::default();
    cfg.protocol = crate::config::ProtocolConfig::for_variant(scale.n, variant);
    cfg.workload.clients = 10;
    cfg.workload.rate = rate;
    cfg.workload.duration_us = scale.duration_us;
    cfg.workload.warmup_us = scale.warmup_us;
    cfg.seed = seed;
    cfg
}

/// Cell 1: V2 at `scale.n` with the compact-payload knob off, then on —
/// one config bit apart, same seed.
pub fn compact_comparison(scale: Scale, rate: f64, seed: u64) -> Vec<CompactPoint> {
    let mut out = Vec::new();
    for (mode, compact) in [("dense", false), ("compact", true)] {
        let mut cfg = cell_config(scale, Variant::V2, rate, seed);
        cfg.protocol.compact_payloads = compact;
        out.push(CompactPoint::from_report(mode, &run_experiment(&cfg)));
    }
    out
}

/// Cell 2: raft / v2 / pull at `scale.n`, one config per variant.
/// Compact payloads on for the epidemic variants — this cell is the
/// new encoding's production posture at scale.
pub fn protocol_metrics(scale: Scale, rate: f64, seed: u64) -> Vec<ProtocolPoint> {
    [Variant::Raft, Variant::V2, Variant::Pull]
        .iter()
        .map(|&variant| {
            let mut cfg = cell_config(scale, variant, rate, seed);
            cfg.protocol.compact_payloads = true;
            ProtocolPoint::from_report(&run_experiment(&cfg))
        })
        .collect()
}

/// Cell 3: the n=10k fleet, single-thread then sharded, same seed.
pub fn fleet_scale(n: usize, fanout: usize, seed: u64, shards: usize) -> FleetCell {
    FleetCell {
        single: converge(n, fanout, 1, &Backend::Native, seed),
        sharded: converge_sharded(n, fanout, 1, &Backend::Native, seed, shards),
    }
}

/// The CI gate — deterministic outcomes only, never wall-clock.
pub fn scale_gate(
    compact: &[CompactPoint],
    protocol: &[ProtocolPoint],
    fleet: &FleetCell,
) -> Result<(), String> {
    // Cell 1: compact payloads change bytes and nothing else.
    let dense = compact
        .iter()
        .find(|p| p.mode == "dense")
        .ok_or("gate: dense point missing")?;
    let packed = compact
        .iter()
        .find(|p| p.mode == "compact")
        .ok_or("gate: compact point missing")?;
    for p in compact {
        if !p.safety_ok {
            return Err(format!("gate: safety violated in the '{}' compact run", p.mode));
        }
        if p.elections > 0 {
            return Err(format!("gate: leader deposed in the '{}' compact run", p.mode));
        }
    }
    if packed.completed != dense.completed || packed.messages != dense.messages {
        return Err(format!(
            "gate: compact encoding perturbed the run (completed {} vs {}, messages {} vs {})",
            packed.completed, dense.completed, packed.messages, dense.messages
        ));
    }
    if dense.completed == 0 {
        return Err("gate: compact cell served no requests".into());
    }
    if packed.leader_egress_bytes >= dense.leader_egress_bytes {
        return Err(format!(
            "gate: compact leader egress {} not strictly below dense {}",
            packed.leader_egress_bytes, dense.leader_egress_bytes
        ));
    }
    if packed.peer_egress_bytes_total >= dense.peer_egress_bytes_total {
        return Err(format!(
            "gate: compact peer egress {} not strictly below dense {}",
            packed.peer_egress_bytes_total, dense.peer_egress_bytes_total
        ));
    }
    // Cell 2: safe, leader-stable and serving at n=2001, with classic
    // Raft's leader egress strictly above both epidemic variants'.
    let find = |name: &str| {
        protocol
            .iter()
            .find(|p| p.variant == name)
            .ok_or_else(|| format!("gate: variant '{name}' missing from the scale cell"))
    };
    for p in protocol {
        if !p.safety_ok {
            return Err(format!("gate: safety violated in the '{}' scale run", p.variant));
        }
        if p.elections > 0 {
            return Err(format!(
                "gate: leader deposed ({} election(s)) in the '{}' scale run",
                p.elections, p.variant
            ));
        }
        if p.completed == 0 {
            return Err(format!("gate: '{}' served no requests at scale", p.variant));
        }
        if p.commit_p99_us == 0 || p.commit_p99_us > 10_000_000 {
            return Err(format!(
                "gate: '{}' commit p99 {}us is not sane",
                p.variant, p.commit_p99_us
            ));
        }
    }
    let raft = find(Variant::Raft.name())?;
    let v2 = find(Variant::V2.name())?;
    let pull = find(Variant::Pull.name())?;
    if raft.leader_egress_bytes <= v2.leader_egress_bytes {
        return Err(format!(
            "gate: classic leader egress {} not strictly above v2's {}",
            raft.leader_egress_bytes, v2.leader_egress_bytes
        ));
    }
    if raft.leader_egress_bytes <= pull.leader_egress_bytes {
        return Err(format!(
            "gate: classic leader egress {} not strictly above pull's {}",
            raft.leader_egress_bytes, pull.leader_egress_bytes
        ));
    }
    // Cell 3: sharding is invisible in the outcome, and the fleet
    // actually converges (the cap in `converge` is 10_000 rounds).
    if fleet.single != fleet.sharded {
        return Err(format!(
            "gate: sharded fleet diverged from single-thread \
             (rounds {} vs {}, messages {} vs {})",
            fleet.sharded.rounds_to_all_commit,
            fleet.single.rounds_to_all_commit,
            fleet.sharded.messages,
            fleet.single.messages
        ));
    }
    if fleet.single.rounds_to_all_commit >= 100 {
        return Err(format!(
            "gate: n={} fleet took {} rounds to converge (cap 100)",
            fleet.single.n, fleet.single.rounds_to_all_commit
        ));
    }
    Ok(())
}

/// Render the whole scenario as the `BENCH_PR8.json` document.
pub fn bench_pr8_json(
    compact_scale: Scale,
    protocol_scale: Scale,
    seed: u64,
    compact: &[CompactPoint],
    protocol: &[ProtocolPoint],
    fleet: &FleetCell,
) -> Json {
    let gate = scale_gate(compact, protocol, fleet);
    Json::obj(vec![
        ("bench", Json::str("simulator-at-scale")),
        ("compact_n", Json::num(compact_scale.n as f64)),
        ("protocol_n", Json::num(protocol_scale.n as f64)),
        ("fleet_n", Json::num(FLEET_N as f64)),
        ("seed", Json::num(seed as f64)),
        ("compact", Json::arr(compact.iter().map(|p| p.to_json()))),
        ("protocol", Json::arr(protocol.iter().map(|p| p.to_json()))),
        ("fleet_single", fleet.single.to_json()),
        ("fleet_sharded", fleet.sharded.to_json()),
        ("gate_scale", Json::Bool(gate.is_ok())),
        (
            "gate_detail",
            match gate {
                Ok(()) => Json::str(
                    "compact encoding byte-only; n=2001 safe and cheaper than classic; \
                     n=10k fleet sharded == single-thread",
                ),
                Err(e) => Json::str(&e),
            },
        ),
    ])
}

/// Print the three cells.
pub fn print_scale(compact: &[CompactPoint], protocol: &[ProtocolPoint], fleet: &FleetCell) {
    println!("\n== compact payloads (V2): dense vs compact encoding ==");
    println!(
        "{:<8} {:>10} {:>10} {:>16} {:>16} {:>12}",
        "mode", "completed", "messages", "leader_bytes", "peer_bytes", "host_us/s"
    );
    for p in compact {
        println!(
            "{:<8} {:>10} {:>10} {:>16} {:>16} {:>12.0}",
            p.mode,
            p.completed,
            p.messages,
            p.leader_egress_bytes,
            p.peer_egress_bytes_total,
            p.host_us_per_sim_sec
        );
    }
    println!("\n== protocol metrics at scale ==");
    println!(
        "{:<8} {:>10} {:>12} {:>14} {:>16} {:>12} {:>12}",
        "variant", "completed", "p99_lat_us", "commit_p99_us", "leader_bytes", "events", "host_us/s"
    );
    for p in protocol {
        println!(
            "{:<8} {:>10} {:>12} {:>14} {:>16} {:>12} {:>12.0}",
            p.variant,
            p.completed,
            p.p99_latency_us,
            p.commit_p99_us,
            p.leader_egress_bytes,
            p.events_processed,
            p.host_us_per_sim_sec
        );
    }
    println!("\n== fleet convergence (n={}, F={}) ==", fleet.single.n, fleet.single.fanout);
    for (label, r) in [("single", &fleet.single), ("sharded", &fleet.sharded)] {
        println!(
            "{:<8} shards={:<3} rounds(first)={:<4} rounds(all)={:<4} messages={:<10} host={:.2}s",
            label,
            r.shards,
            r.rounds_to_first_commit,
            r.rounds_to_all_commit,
            r.messages,
            r.host_secs
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Tiny scales: the cells' *mechanics* (gate wiring, JSON shape) are
    // testable without four-digit n; `bench-pr8` itself runs the real
    // sizes in the scale-smoke CI job.
    fn tiny_compact() -> Scale {
        Scale { reps: 1, duration_us: 1_500_000, warmup_us: 300_000, n: 40 }
    }

    fn tiny_protocol() -> Scale {
        Scale { reps: 1, duration_us: 1_500_000, warmup_us: 300_000, n: 15 }
    }

    fn tiny_cells() -> (Vec<CompactPoint>, Vec<ProtocolPoint>, FleetCell) {
        (
            compact_comparison(tiny_compact(), 300.0, 7),
            protocol_metrics(tiny_protocol(), 300.0, 7),
            fleet_scale(201, 5, 7, 3),
        )
    }

    #[test]
    fn gate_passes_at_tiny_scale_and_rejects_tampering() {
        let (compact, protocol, fleet) = tiny_cells();
        scale_gate(&compact, &protocol, &fleet).expect("tiny-scale gate");
        // Tamper 1: pretend compact encoding changed the outcome.
        let mut bad = compact.clone();
        bad[1].completed += 1;
        assert!(scale_gate(&bad, &protocol, &fleet).is_err());
        // Tamper 2: pretend compact encoding saved nothing.
        let mut bad = compact.clone();
        bad[1].leader_egress_bytes = bad[0].leader_egress_bytes;
        assert!(scale_gate(&bad, &protocol, &fleet).is_err());
        // Tamper 3: pretend classic got cheaper than v2.
        let mut bad = protocol.clone();
        for p in bad.iter_mut() {
            if p.variant == Variant::Raft.name() {
                p.leader_egress_bytes = 0;
            }
        }
        assert!(scale_gate(&compact, &bad, &fleet).is_err());
        // Tamper 4: pretend the shards diverged.
        let mut bad = fleet.clone();
        bad.sharded.messages += 1;
        assert!(scale_gate(&compact, &protocol, &bad).is_err());
    }

    #[test]
    fn bench_json_has_cells_and_gate() {
        let (compact, protocol, fleet) = tiny_cells();
        let j = bench_pr8_json(tiny_compact(), tiny_protocol(), 7, &compact, &protocol, &fleet);
        assert_eq!(j.get("compact").and_then(|v| v.as_arr()).unwrap().len(), 2);
        assert_eq!(j.get("protocol").and_then(|v| v.as_arr()).unwrap().len(), 3);
        assert!(j.get("gate_scale").and_then(|g| g.as_bool()).is_some());
        let text = j.to_string_pretty();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(
            parsed.get("bench").and_then(|b| b.as_str()),
            Some("simulator-at-scale")
        );
    }
}
