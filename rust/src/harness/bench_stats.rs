//! Criterion-lite: a small measurement harness for the `cargo bench`
//! binaries (criterion itself is unavailable offline). Provides warmup,
//! repeated sampling, and mean ± stddev reporting for closures, plus
//! throughput formatting.

use crate::util::stats::{summarize, Summary};
use std::time::Instant;

/// Measurement result for one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Nanoseconds per iteration.
    pub ns_per_iter: Summary,
    pub iters_per_sample: u64,
    pub samples: usize,
}

impl BenchResult {
    pub fn ops_per_sec(&self) -> f64 {
        if self.ns_per_iter.mean == 0.0 {
            return 0.0;
        }
        1e9 / self.ns_per_iter.mean
    }

    pub fn report_line(&self) -> String {
        format!(
            "{:<44} {:>12.1} ns/iter (±{:>8.1})  {:>14.0} ops/s",
            self.name,
            self.ns_per_iter.mean,
            self.ns_per_iter.std_dev,
            self.ops_per_sec()
        )
    }
}

/// Benchmark a closure: auto-calibrated iteration count, `samples`
/// measured samples after warmup.
pub fn bench<F: FnMut()>(name: &str, samples: usize, mut f: F) -> BenchResult {
    // Calibrate: find an iteration count that runs >= ~2ms per sample.
    let mut iters: u64 = 1;
    loop {
        let t = Instant::now();
        for _ in 0..iters {
            f();
        }
        let dt = t.elapsed();
        if dt.as_micros() >= 2_000 || iters >= 1 << 24 {
            break;
        }
        iters *= 4;
    }
    // Warmup.
    for _ in 0..iters {
        f();
    }
    // Measure.
    let mut per_iter = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..iters {
            f();
        }
        per_iter.push(t.elapsed().as_nanos() as f64 / iters as f64);
    }
    BenchResult {
        name: name.to_string(),
        ns_per_iter: summarize(&per_iter),
        iters_per_sample: iters,
        samples,
    }
}

/// Prevent the optimizer from eliding a value (std::hint::black_box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut acc = 0u64;
        let r = bench("noop-ish", 5, || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.ns_per_iter.mean > 0.0);
        assert!(r.iters_per_sample >= 1);
        assert_eq!(r.samples, 5);
        assert!(r.ops_per_sec() > 0.0);
        assert!(r.report_line().contains("noop-ish"));
    }

    #[test]
    fn slower_work_measures_slower() {
        let fast = bench("fast", 3, || {
            black_box(1u64 + 1);
        });
        let slow = bench("slow", 3, || {
            let mut s = 0u64;
            for i in 0..1000u64 {
                s = s.wrapping_add(black_box(i));
            }
            black_box(s);
        });
        assert!(slow.ns_per_iter.mean > fast.ns_per_iter.mean);
    }
}
