//! PR 2 measurement plumbing: the leader-egress comparison across every
//! registered replication variant at the paper's n=51 scale.
//!
//! This is the scenario behind `epiraft bench-pr2`, the committed
//! `BENCH_PR2.json`, and CI's `bench-smoke` gate (the pull variant's
//! leader egress must be *strictly below* classic Raft's). Every later
//! variant lands one registry row and shows up here automatically —
//! the harness iterates the strategy registry, not a variant list.

use super::figures::Scale;
use crate::config::Config;
use crate::raft::{strategy, Variant};
use crate::sim::{run_experiment, SimReport};
use crate::util::json::Json;

/// One variant's egress measurements at the shared scenario point.
#[derive(Clone, Debug)]
pub struct EgressPoint {
    pub variant: &'static str,
    pub leader_egress_bytes: u64,
    pub peer_egress_bytes_total: u64,
    pub peer_egress_bytes_max: u64,
    /// Leader bytes per committed entry — the normalized form of the claim
    /// (robust to small throughput differences between variants).
    pub leader_bytes_per_commit: f64,
    pub throughput: f64,
    pub completed: u64,
    pub max_commit: u64,
    pub safety_ok: bool,
    /// Elections during the run. Egress is attributed to the *end-of-run*
    /// leader, so the split is only meaningful when the leader was stable —
    /// the gate rejects runs where this is nonzero.
    pub elections: u64,
}

impl EgressPoint {
    fn from_report(r: &SimReport) -> EgressPoint {
        EgressPoint {
            variant: r.variant,
            leader_egress_bytes: r.leader_egress_bytes,
            peer_egress_bytes_total: r.peer_egress_bytes_total,
            peer_egress_bytes_max: r.peer_egress_bytes_max,
            leader_bytes_per_commit: r.leader_egress_bytes as f64 / r.max_commit.max(1) as f64,
            throughput: r.throughput,
            completed: r.completed,
            max_commit: r.max_commit,
            safety_ok: r.safety_ok,
            elections: r.elections,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("variant", Json::str(self.variant)),
            ("leader_egress_bytes", Json::num(self.leader_egress_bytes as f64)),
            (
                "peer_egress_bytes_total",
                Json::num(self.peer_egress_bytes_total as f64),
            ),
            ("peer_egress_bytes_max", Json::num(self.peer_egress_bytes_max as f64)),
            ("leader_bytes_per_commit", Json::num(self.leader_bytes_per_commit)),
            ("throughput", Json::num(self.throughput)),
            ("completed", Json::num(self.completed as f64)),
            ("max_commit", Json::num(self.max_commit as f64)),
            ("safety_ok", Json::Bool(self.safety_ok)),
            ("elections", Json::num(self.elections as f64)),
        ])
    }
}

/// The deterministic scenario: every registered variant under one config
/// (same n, same seed, same rate-throttled workload), differing only in
/// `protocol.variant`. Rate-throttled so each variant replicates the same
/// offered load and raw egress bytes are directly comparable.
pub fn leader_egress_comparison(scale: Scale, rate: f64, seed: u64) -> Vec<EgressPoint> {
    strategy::REGISTRY
        .iter()
        .map(|info| {
            let mut cfg = Config::default();
            cfg.protocol = crate::config::ProtocolConfig::for_variant(scale.n, info.variant);
            cfg.workload.clients = 10;
            cfg.workload.rate = rate;
            cfg.workload.duration_us = scale.duration_us;
            cfg.workload.warmup_us = scale.warmup_us;
            cfg.seed = seed;
            // Safety is carried per point (`safety_ok`), not asserted here:
            // `egress_gate` reports a violation through the Result path, so
            // `bench-pr2` / CI fail with a message instead of a panic.
            EgressPoint::from_report(&run_experiment(&cfg))
        })
        .collect()
}

/// The CI gate: every measured run safe and leader-stable, and the pull
/// variant's leader egress strictly below classic's (raw bytes *and*
/// normalized per committed entry).
pub fn egress_gate(points: &[EgressPoint]) -> Result<(), String> {
    let find = |name: &str| {
        points
            .iter()
            .find(|p| p.variant == name)
            .ok_or_else(|| format!("gate: variant '{name}' missing from results"))
    };
    // Safety first, for *every* measured variant (not just the two gated
    // ones) — an unsafe run's egress numbers are meaningless.
    if let Some(bad) = points.iter().find(|p| !p.safety_ok) {
        return Err(format!("gate: safety violated in the '{}' egress run", bad.variant));
    }
    // Egress bytes are attributed to the end-of-run leader (the sim's
    // `leader_egress_bytes` split), so a deposed leader mid-run silently
    // mis-attributes the claim's numbers — only stable-leader runs compare.
    if let Some(bad) = points.iter().find(|p| p.elections > 0) {
        return Err(format!(
            "gate: leader deposed ({} election(s)) in the '{}' egress run — split not comparable",
            bad.elections, bad.variant
        ));
    }
    let raft = find(Variant::Raft.name())?;
    let pull = find(Variant::Pull.name())?;
    if pull.completed == 0 {
        return Err("gate: pull variant served no requests".into());
    }
    if pull.leader_egress_bytes >= raft.leader_egress_bytes {
        return Err(format!(
            "gate: pull leader egress {} is not strictly below classic's {}",
            pull.leader_egress_bytes, raft.leader_egress_bytes
        ));
    }
    if pull.leader_bytes_per_commit >= raft.leader_bytes_per_commit {
        return Err(format!(
            "gate: pull leader bytes/commit {:.1} not below classic's {:.1}",
            pull.leader_bytes_per_commit, raft.leader_bytes_per_commit
        ));
    }
    Ok(())
}

/// Render the whole scenario (config + per-variant points + gate verdict)
/// as the `BENCH_PR2.json` document.
pub fn bench_pr2_json(
    scale: Scale,
    rate: f64,
    seed: u64,
    points: &[EgressPoint],
) -> Json {
    let gate = egress_gate(points);
    Json::obj(vec![
        ("bench", Json::str("leader-egress-by-variant")),
        ("n", Json::num(scale.n as f64)),
        ("rate", Json::num(rate)),
        ("duration_us", Json::num(scale.duration_us as f64)),
        ("warmup_us", Json::num(scale.warmup_us as f64)),
        ("seed", Json::num(seed as f64)),
        ("variants", Json::arr(points.iter().map(|p| p.to_json()))),
        ("gate_pull_below_raft", Json::Bool(gate.is_ok())),
        (
            "gate_detail",
            match gate {
                Ok(()) => Json::str("pull leader egress strictly below classic"),
                Err(e) => Json::str(&e),
            },
        ),
    ])
}

/// Print the comparison table.
pub fn print_egress(points: &[EgressPoint]) {
    println!("\n== leader egress by variant (replica-to-replica bytes, whole run) ==");
    println!(
        "{:<8} {:>16} {:>18} {:>16} {:>12} {:>10}",
        "variant", "leader_bytes", "bytes/commit", "peer_total", "tput(req/s)", "safety"
    );
    for p in points {
        println!(
            "{:<8} {:>16} {:>18.1} {:>16} {:>12.1} {:>10}",
            p.variant,
            p.leader_egress_bytes,
            p.leader_bytes_per_commit,
            p.peer_egress_bytes_total,
            p.throughput,
            if p.safety_ok { "OK" } else { "VIOLATED" }
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Scale {
        Scale { reps: 1, duration_us: 1_500_000, warmup_us: 300_000, n: 7 }
    }

    #[test]
    fn comparison_covers_every_registered_variant() {
        let pts = leader_egress_comparison(tiny(), 300.0, 7);
        assert_eq!(pts.len(), strategy::REGISTRY.len());
        for p in &pts {
            assert!(p.safety_ok, "{}", p.variant);
            assert!(p.leader_egress_bytes > 0, "{}", p.variant);
            assert!(p.max_commit > 0, "{}", p.variant);
        }
    }

    #[test]
    fn gate_passes_at_moderate_scale_and_rejects_tampering() {
        // n=15, not the tiny n=7: the leader-egress gap scales with n
        // (classic broadcasts to n-1; pull's seed fanout is constant), and
        // at very small n the seed rounds' batch-base redundancy can eat
        // the margin. CI's gate runs the claim at the paper's n=51.
        let scale = Scale { reps: 1, duration_us: 1_500_000, warmup_us: 300_000, n: 15 };
        let pts = leader_egress_comparison(scale, 500.0, 7);
        egress_gate(&pts).expect("pull must beat classic on leader egress");
        // Tamper: inflate pull's egress — the gate must fail loudly.
        let mut bad = pts.clone();
        for p in bad.iter_mut() {
            if p.variant == "pull" {
                p.leader_egress_bytes = u64::MAX;
                p.leader_bytes_per_commit = f64::MAX;
            }
        }
        assert!(egress_gate(&bad).is_err());
    }

    #[test]
    fn bench_json_has_gate_and_variants() {
        let pts = leader_egress_comparison(tiny(), 300.0, 7);
        let j = bench_pr2_json(tiny(), 300.0, 7, &pts);
        assert_eq!(
            j.get("variants").and_then(|v| v.as_arr()).unwrap().len(),
            strategy::REGISTRY.len()
        );
        // The verdict is present either way (its value at tiny n is not the
        // claim — see gate_passes_at_moderate_scale_and_rejects_tampering).
        assert!(j.get("gate_pull_below_raft").and_then(|g| g.as_bool()).is_some());
        // Round-trips through the in-tree parser.
        let text = j.to_string_pretty();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.get("bench").and_then(|b| b.as_str()), Some("leader-egress-by-variant"));
    }
}
