//! PR 3 measurement plumbing: fixed vs adaptive fanout at n=101, under a
//! clean network and under Gilbert–Elliott burst loss.
//!
//! This is the scenario behind `epiraft bench-pr3`, the committed
//! `BENCH_PR3.json`, and CI's `bench-smoke` gate for the adaptive
//! controller (`raft::strategy::disseminate`): with `[protocol.adaptive]`
//! enabled, the pull variant's steady-state leader egress must come in
//! *strictly below* its own fixed-fanout baseline while follower commit
//! latency (p99 of the leader-append→follower-commit interval) stays
//! within 1.5x — i.e. the controller buys egress without giving the
//! latency back. The v1 gossip variant rides along for the report (its
//! relay floor keeps it live; see `disseminate::GOSSIP_FLOOR`) but is not
//! latency-gated: trading relay amplification for egress is dissemination
//! -shape-dependent, and the claim under test is the pull one.

use super::figures::Scale;
use crate::config::Config;
use crate::raft::Variant;
use crate::sim::{run_experiment, SimReport};
use crate::util::json::Json;

/// Network conditions a comparison cell runs under.
const CLEAN: &str = "clean";
const BURST: &str = "burst";

/// One (variant, mode, network) cell of the comparison grid.
#[derive(Clone, Debug)]
pub struct AdaptivePoint {
    pub variant: &'static str,
    /// `"fixed"` (static `protocol.fanout`) or `"adaptive"`.
    pub mode: &'static str,
    /// `"clean"` or `"burst"` (Gilbert–Elliott).
    pub network: &'static str,
    pub leader_egress_bytes: u64,
    pub peer_egress_bytes_total: u64,
    /// Leader bytes per committed entry (normalized form of the claim).
    pub leader_bytes_per_commit: f64,
    pub throughput: f64,
    pub completed: u64,
    pub max_commit: u64,
    /// Leader-append→follower-commit interval (µs).
    pub p50_commit_us: u64,
    pub p99_commit_us: u64,
    /// Controller trajectory (from `Counters` via `SimReport`).
    pub fanout_current: u64,
    pub fanout_adaptations: u64,
    pub fanout_min_seen: u64,
    pub fanout_max_seen: u64,
    pub elections: u64,
    pub safety_ok: bool,
}

impl AdaptivePoint {
    fn from_report(mode: &'static str, network: &'static str, r: &SimReport) -> AdaptivePoint {
        AdaptivePoint {
            variant: r.variant,
            mode,
            network,
            leader_egress_bytes: r.leader_egress_bytes,
            peer_egress_bytes_total: r.peer_egress_bytes_total,
            leader_bytes_per_commit: r.leader_egress_bytes as f64 / r.max_commit.max(1) as f64,
            throughput: r.throughput,
            completed: r.completed,
            max_commit: r.max_commit,
            p50_commit_us: r.commit_interval.p50(),
            p99_commit_us: r.commit_interval.p99(),
            fanout_current: r.fanout_current,
            fanout_adaptations: r.fanout_adaptations,
            fanout_min_seen: r.fanout_min_seen,
            fanout_max_seen: r.fanout_max_seen,
            elections: r.elections,
            safety_ok: r.safety_ok,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("variant", Json::str(self.variant)),
            ("mode", Json::str(self.mode)),
            ("network", Json::str(self.network)),
            ("leader_egress_bytes", Json::num(self.leader_egress_bytes as f64)),
            (
                "peer_egress_bytes_total",
                Json::num(self.peer_egress_bytes_total as f64),
            ),
            ("leader_bytes_per_commit", Json::num(self.leader_bytes_per_commit)),
            ("throughput", Json::num(self.throughput)),
            ("completed", Json::num(self.completed as f64)),
            ("max_commit", Json::num(self.max_commit as f64)),
            ("p50_commit_us", Json::num(self.p50_commit_us as f64)),
            ("p99_commit_us", Json::num(self.p99_commit_us as f64)),
            ("fanout_current", Json::num(self.fanout_current as f64)),
            ("fanout_adaptations", Json::num(self.fanout_adaptations as f64)),
            ("fanout_min_seen", Json::num(self.fanout_min_seen as f64)),
            ("fanout_max_seen", Json::num(self.fanout_max_seen as f64)),
            ("elections", Json::num(self.elections as f64)),
            ("safety_ok", Json::Bool(self.safety_ok)),
        ])
    }
}

/// Variants in the grid: the gated pull pair plus v1 for the report.
fn grid_variants() -> [Variant; 2] {
    [Variant::Pull, Variant::V1]
}

/// Run the full comparison grid: {pull, v1} x {fixed, adaptive} x
/// {clean, burst} under one rate-throttled workload (same n, same seed —
/// cells differ only in the adaptive switch and the network impairment).
pub fn adaptive_comparison(scale: Scale, rate: f64, seed: u64) -> Vec<AdaptivePoint> {
    let mut out = Vec::new();
    for variant in grid_variants() {
        for network in [CLEAN, BURST] {
            for mode in ["fixed", "adaptive"] {
                let mut cfg = Config {
                    protocol: crate::config::ProtocolConfig::for_variant(scale.n, variant),
                    ..Config::default()
                };
                cfg.protocol.adaptive.enabled = mode == "adaptive";
                cfg.workload.clients = 10;
                cfg.workload.rate = rate;
                cfg.workload.duration_us = scale.duration_us;
                cfg.workload.warmup_us = scale.warmup_us;
                cfg.seed = seed;
                if network == BURST {
                    // ~20-packet bursts dropping 80%, entered by ~1% of
                    // packets per link (the PR 1 Gilbert–Elliott knobs).
                    cfg.network.ge_good_to_bad = 0.01;
                    cfg.network.ge_bad_to_good = 0.05;
                    cfg.network.ge_loss_good = 0.0;
                    cfg.network.ge_loss_bad = 0.8;
                }
                out.push(AdaptivePoint::from_report(mode, network, &run_experiment(&cfg)));
            }
        }
    }
    out
}

fn find<'a>(
    points: &'a [AdaptivePoint],
    variant: &str,
    mode: &str,
    network: &str,
) -> Result<&'a AdaptivePoint, String> {
    points
        .iter()
        .find(|p| p.variant == variant && p.mode == mode && p.network == network)
        .ok_or_else(|| format!("gate: cell {variant}/{mode}/{network} missing from results"))
}

/// The CI gate (`epiraft bench-pr3` exit status):
///
/// * every measured cell is safe and committed something;
/// * clean cells kept the bootstrap leader (egress attribution — same
///   argument as the PR 2 gate);
/// * pull/adaptive/clean: leader egress strictly below pull/fixed/clean
///   (raw and per committed entry), p99 commit interval within 1.5x, and
///   the controller demonstrably adapted (trajectory moved, settled below
///   the static fanout).
pub fn adaptive_gate(points: &[AdaptivePoint]) -> Result<(), String> {
    if let Some(bad) = points.iter().find(|p| !p.safety_ok) {
        return Err(format!(
            "gate: safety violated in the {}/{}/{} run",
            bad.variant, bad.mode, bad.network
        ));
    }
    if let Some(bad) = points.iter().find(|p| p.max_commit == 0) {
        return Err(format!(
            "gate: nothing committed in the {}/{}/{} run",
            bad.variant, bad.mode, bad.network
        ));
    }
    if let Some(bad) = points.iter().find(|p| p.network == CLEAN && p.elections > 0) {
        return Err(format!(
            "gate: leader deposed ({} election(s)) in the clean {}/{} run",
            bad.elections, bad.variant, bad.mode
        ));
    }
    let pull = Variant::Pull.name();
    let fixed = find(points, pull, "fixed", CLEAN)?;
    let adaptive = find(points, pull, "adaptive", CLEAN)?;
    if adaptive.completed == 0 {
        return Err("gate: adaptive pull served no requests".into());
    }
    if adaptive.leader_egress_bytes >= fixed.leader_egress_bytes {
        return Err(format!(
            "gate: adaptive leader egress {} is not strictly below fixed's {}",
            adaptive.leader_egress_bytes, fixed.leader_egress_bytes
        ));
    }
    if adaptive.leader_bytes_per_commit >= fixed.leader_bytes_per_commit {
        return Err(format!(
            "gate: adaptive leader bytes/commit {:.1} not below fixed's {:.1}",
            adaptive.leader_bytes_per_commit, fixed.leader_bytes_per_commit
        ));
    }
    if fixed.p99_commit_us == 0 {
        return Err("gate: fixed baseline recorded no commit intervals".into());
    }
    if adaptive.p99_commit_us as f64 > fixed.p99_commit_us as f64 * 1.5 {
        return Err(format!(
            "gate: adaptive p99 commit {}us exceeds 1.5x fixed's {}us",
            adaptive.p99_commit_us, fixed.p99_commit_us
        ));
    }
    if adaptive.fanout_adaptations == 0 {
        return Err("gate: adaptive run never adapted (controller inert?)".into());
    }
    if adaptive.fanout_current >= fixed.fanout_current {
        return Err(format!(
            "gate: adaptive steady-state fanout {} did not settle below the static {}",
            adaptive.fanout_current, fixed.fanout_current
        ));
    }
    Ok(())
}

/// Render the whole scenario (config + grid + gate verdict) as the
/// `BENCH_PR3.json` document.
pub fn bench_pr3_json(scale: Scale, rate: f64, seed: u64, points: &[AdaptivePoint]) -> Json {
    let gate = adaptive_gate(points);
    Json::obj(vec![
        ("bench", Json::str("adaptive-vs-fixed-fanout")),
        ("n", Json::num(scale.n as f64)),
        ("rate", Json::num(rate)),
        ("duration_us", Json::num(scale.duration_us as f64)),
        ("warmup_us", Json::num(scale.warmup_us as f64)),
        ("seed", Json::num(seed as f64)),
        ("points", Json::arr(points.iter().map(|p| p.to_json()))),
        ("gate_adaptive_below_fixed", Json::Bool(gate.is_ok())),
        (
            "gate_detail",
            match gate {
                Ok(()) => Json::str(
                    "adaptive pull leader egress strictly below fixed, p99 commit within 1.5x",
                ),
                Err(e) => Json::str(&e),
            },
        ),
    ])
}

/// Print the comparison table.
pub fn print_adaptive(points: &[AdaptivePoint]) {
    println!("\n== fixed vs adaptive fanout (leader egress / commit interval) ==");
    println!(
        "{:<6} {:<9} {:<6} {:>14} {:>14} {:>12} {:>8} {:>7} {:>8}",
        "var",
        "mode",
        "net",
        "leader_bytes",
        "p99_commit_us",
        "tput(req/s)",
        "fanout",
        "adapts",
        "safety"
    );
    for p in points {
        println!(
            "{:<6} {:<9} {:<6} {:>14} {:>14} {:>12.1} {:>8} {:>7} {:>8}",
            p.variant,
            p.mode,
            p.network,
            p.leader_egress_bytes,
            p.p99_commit_us,
            p.throughput,
            p.fanout_current,
            p.fanout_adaptations,
            if p.safety_ok { "OK" } else { "VIOLATED" }
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Scale {
        Scale { reps: 1, duration_us: 1_500_000, warmup_us: 300_000, n: 7 }
    }

    #[test]
    fn comparison_covers_the_full_grid() {
        let pts = adaptive_comparison(tiny(), 300.0, 11);
        assert_eq!(pts.len(), 8, "2 variants x 2 modes x 2 networks");
        for p in &pts {
            assert!(p.safety_ok, "{}/{}/{}", p.variant, p.mode, p.network);
            assert!(p.max_commit > 0, "{}/{}/{}", p.variant, p.mode, p.network);
        }
        // Fixed cells never adapt; adaptive clean cells do.
        for p in &pts {
            if p.mode == "fixed" {
                assert_eq!(p.fanout_adaptations, 0, "{}/{}", p.variant, p.network);
            }
        }
    }

    #[test]
    fn gate_passes_at_moderate_scale_and_rejects_tampering() {
        // n=15 rather than the tiny n=7: like the PR 2 egress gate, the
        // seed-fanout gap needs a few peers to show through the pull-reply
        // share of leader egress. CI runs the claim at n=101.
        let scale = Scale { reps: 1, duration_us: 1_500_000, warmup_us: 300_000, n: 15 };
        let pts = adaptive_comparison(scale, 400.0, 11);
        adaptive_gate(&pts).expect("adaptive pull must beat its fixed baseline");
        let mut bad = pts.clone();
        for p in bad.iter_mut() {
            if p.variant == "pull" && p.mode == "adaptive" && p.network == "clean" {
                p.leader_egress_bytes = u64::MAX;
                p.leader_bytes_per_commit = f64::MAX;
            }
        }
        assert!(adaptive_gate(&bad).is_err(), "inflated egress must fail the gate");
        let mut bad = pts.clone();
        for p in bad.iter_mut() {
            if p.variant == "pull" && p.mode == "adaptive" && p.network == "clean" {
                p.p99_commit_us = u64::MAX;
            }
        }
        assert!(adaptive_gate(&bad).is_err(), "blown latency must fail the gate");
    }

    #[test]
    fn bench_json_round_trips_with_gate_fields() {
        let pts = adaptive_comparison(tiny(), 300.0, 11);
        let j = bench_pr3_json(tiny(), 300.0, 11, &pts);
        assert_eq!(j.get("points").and_then(|v| v.as_arr()).unwrap().len(), 8);
        assert!(j.get("gate_adaptive_below_fixed").and_then(|g| g.as_bool()).is_some());
        let parsed = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(
            parsed.get("bench").and_then(|b| b.as_str()),
            Some("adaptive-vs-fixed-fanout")
        );
    }
}
