//! Figure regeneration harness: one driver per table/figure of the paper's
//! evaluation (§4.2), each printing the same series the paper plots and
//! emitting machine-readable JSON under `target/results/`.
//!
//! The paper runs every experiment 3× and plots means (§4.1) — `reps`
//! controls that here.

use crate::config::{presets, Config};
use crate::raft::Variant;
use crate::sim::{run_experiment, SimReport};
use crate::util::json::Json;
use crate::util::stats::summarize;

/// Aggregate of repeated runs at one experimental point.
#[derive(Clone, Debug)]
pub struct Point {
    pub variant: &'static str,
    pub x: f64,
    pub throughput: f64,
    pub mean_latency_us: f64,
    pub p99_latency_us: f64,
    pub leader_cpu: f64,
    pub follower_cpu_mean: f64,
    pub follower_cpu_max: f64,
    pub commit_p50_us: f64,
    pub commit_p99_us: f64,
    pub reps: usize,
}

impl Point {
    fn from_reports(variant: &'static str, x: f64, reports: &[SimReport]) -> Point {
        let f = |g: &dyn Fn(&SimReport) -> f64| {
            summarize(&reports.iter().map(g).collect::<Vec<_>>()).mean
        };
        Point {
            variant,
            x,
            throughput: f(&|r| r.throughput),
            mean_latency_us: f(&|r| r.mean_latency_us),
            p99_latency_us: f(&|r| r.p99_latency_us as f64),
            leader_cpu: f(&|r| r.leader_cpu),
            follower_cpu_mean: f(&|r| r.follower_cpu_mean),
            follower_cpu_max: f(&|r| r.follower_cpu_max),
            commit_p50_us: f(&|r| r.commit_interval.p50() as f64),
            commit_p99_us: f(&|r| r.commit_interval.p99() as f64),
            reps: reports.len(),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("variant", Json::str(self.variant)),
            ("x", Json::num(self.x)),
            ("throughput", Json::num(self.throughput)),
            ("mean_latency_us", Json::num(self.mean_latency_us)),
            ("p99_latency_us", Json::num(self.p99_latency_us)),
            ("leader_cpu", Json::num(self.leader_cpu)),
            ("follower_cpu_mean", Json::num(self.follower_cpu_mean)),
            ("follower_cpu_max", Json::num(self.follower_cpu_max)),
            ("commit_p50_us", Json::num(self.commit_p50_us)),
            ("commit_p99_us", Json::num(self.commit_p99_us)),
            ("reps", Json::num(self.reps as f64)),
        ])
    }
}

/// Run `reps` seeds of `cfg` and aggregate.
pub fn run_point(variant: &'static str, x: f64, cfg: &Config, reps: usize) -> Point {
    let reports: Vec<SimReport> = (0..reps)
        .map(|rep| {
            let mut c = cfg.clone();
            c.seed = cfg.seed + rep as u64 * 7919;
            let r = run_experiment(&c);
            assert!(r.safety_ok, "safety violated at {variant} x={x} rep={rep}");
            r
        })
        .collect();
    Point::from_reports(variant, x, &reports)
}

/// Experiment scale knobs (`--quick` shrinks everything for smoke runs).
#[derive(Clone, Copy, Debug)]
pub struct Scale {
    pub reps: usize,
    pub duration_us: u64,
    pub warmup_us: u64,
    pub n: usize,
}

impl Scale {
    pub fn paper() -> Self {
        Self { reps: 3, duration_us: 10_000_000, warmup_us: 2_000_000, n: 51 }
    }

    pub fn quick() -> Self {
        Self { reps: 1, duration_us: 3_000_000, warmup_us: 500_000, n: 51 }
    }

    fn apply(&self, cfg: &mut Config) {
        cfg.workload.duration_us = self.duration_us;
        cfg.workload.warmup_us = self.warmup_us;
    }
}

/// Fig 4 — mean latency vs request rate; 51 replicas, 100 clients (§4.2).
pub fn fig4(scale: Scale, rates: &[f64]) -> Vec<Point> {
    let mut out = Vec::new();
    for variant in Variant::ALL {
        for &rate in rates {
            let mut cfg = presets::fig4(variant, rate);
            cfg.protocol.n = scale.n;
            scale.apply(&mut cfg);
            out.push(run_point(variant.name(), rate, &cfg, scale.reps));
        }
    }
    out
}

pub fn fig4_default_rates() -> Vec<f64> {
    vec![100.0, 200.0, 400.0, 800.0, 1500.0, 2500.0, 4000.0, 6000.0]
}

/// Fig 5 — CPU usage vs client request rate; 51 replicas, 10 clients.
pub fn fig5(scale: Scale, rates: &[f64]) -> Vec<Point> {
    let mut out = Vec::new();
    for variant in Variant::ALL {
        for &rate in rates {
            let mut cfg = presets::fig56(variant, scale.n, rate);
            scale.apply(&mut cfg);
            out.push(run_point(variant.name(), rate, &cfg, scale.reps));
        }
    }
    out
}

pub fn fig5_default_rates() -> Vec<f64> {
    vec![50.0, 100.0, 200.0, 400.0, 800.0, 1200.0, 1600.0]
}

/// Fig 6 — CPU usage vs number of replicas; 10 unthrottled clients.
pub fn fig6(scale: Scale, ns: &[usize]) -> Vec<Point> {
    fig6_rate(scale, ns, 0.0)
}

/// Fig 6 at a fixed sub-saturation rate: the unthrottled closed loop pins
/// saturated leaders at 100% CPU (scaling then shows as throughput
/// decline); a fixed rate exposes the paper's rising-CPU-with-n curves
/// directly. EXPERIMENTS.md reports both.
pub fn fig6_rate(scale: Scale, ns: &[usize], rate: f64) -> Vec<Point> {
    let mut out = Vec::new();
    for variant in Variant::ALL {
        for &n in ns {
            let mut cfg = presets::fig56(variant, n, rate);
            scale.apply(&mut cfg);
            out.push(run_point(variant.name(), n as f64, &cfg, scale.reps));
        }
    }
    out
}

pub fn fig6_default_ns() -> Vec<usize> {
    vec![5, 11, 21, 31, 41, 51]
}

/// Fig 7 — CDF of the leader-receive→replica-commit interval at a fixed
/// moderate load. Returns `(variant, cdf points)` per variant.
pub fn fig7(scale: Scale, rate: f64) -> Vec<(&'static str, Vec<(u64, f64)>)> {
    let mut out = Vec::new();
    for variant in Variant::ALL {
        let mut cfg = presets::fig4(variant, rate);
        cfg.protocol.n = scale.n;
        scale.apply(&mut cfg);
        let report = run_experiment(&cfg);
        assert!(report.safety_ok);
        out.push((variant.name(), report.commit_interval.cdf()));
    }
    out
}

/// §6 headline numbers: max throughput ratio (V1/Raft) and leader CPU
/// ratio (V2/Raft at matched feasible load).
pub struct Headline {
    pub raft_max_tput: f64,
    pub v1_max_tput: f64,
    pub v2_max_tput: f64,
    pub tput_ratio_v1: f64,
    pub raft_leader_cpu: f64,
    pub v2_leader_cpu: f64,
    pub cpu_ratio_v2: f64,
}

pub fn headline(scale: Scale) -> Headline {
    // Max throughput: unthrottled 100 clients.
    let max_tput = |variant| {
        let mut cfg = presets::fig4(variant, 0.0);
        cfg.protocol.n = scale.n;
        scale.apply(&mut cfg);
        run_point(Variant::name(variant), 0.0, &cfg, scale.reps).throughput
    };
    let raft_max_tput = max_tput(Variant::Raft);
    let v1_max_tput = max_tput(Variant::V1);
    let v2_max_tput = max_tput(Variant::V2);
    // Leader CPU at a load all three sustain (10 clients, unthrottled is
    // self-limiting for raft; use the paper's 10-client closed loop).
    let leader_cpu = |variant| {
        let mut cfg = presets::fig56(variant, scale.n, 0.0);
        scale.apply(&mut cfg);
        run_point(Variant::name(variant), 0.0, &cfg, scale.reps).leader_cpu
    };
    let raft_leader_cpu = leader_cpu(Variant::Raft);
    let v2_leader_cpu = leader_cpu(Variant::V2);
    Headline {
        raft_max_tput,
        v1_max_tput,
        v2_max_tput,
        tput_ratio_v1: v1_max_tput / raft_max_tput.max(1e-9),
        raft_leader_cpu,
        v2_leader_cpu,
        cpu_ratio_v2: v2_leader_cpu / raft_leader_cpu.max(1e-9),
    }
}

// ---------------------------------------------------------------------------
// Output helpers
// ---------------------------------------------------------------------------

/// Print a series table grouped by variant.
pub fn print_points(title: &str, x_label: &str, points: &[Point]) {
    println!("\n== {title} ==");
    println!(
        "{:<8} {:>10} {:>12} {:>14} {:>12} {:>12} {:>12} {:>12}",
        "variant", x_label, "tput(req/s)", "lat_mean(us)", "lat_p99", "cpu_lead", "cpu_flw", "commit_p50"
    );
    for p in points {
        println!(
            "{:<8} {:>10.0} {:>12.1} {:>14.1} {:>12.1} {:>11.1}% {:>11.1}% {:>12.0}",
            p.variant,
            p.x,
            p.throughput,
            p.mean_latency_us,
            p.p99_latency_us,
            p.leader_cpu * 100.0,
            p.follower_cpu_mean * 100.0,
            p.commit_p50_us
        );
    }
}

/// Write points as JSON to `target/results/<name>.json`.
pub fn write_points_json(name: &str, points: &[Point]) -> std::io::Result<String> {
    let dir = "target/results";
    std::fs::create_dir_all(dir)?;
    let path = format!("{dir}/{name}.json");
    let j = Json::arr(points.iter().map(|p| p.to_json()));
    std::fs::write(&path, j.to_string_pretty())?;
    Ok(path)
}

/// Write Fig-7 CDFs as JSON.
pub fn write_cdfs_json(
    name: &str,
    cdfs: &[(&'static str, Vec<(u64, f64)>)],
) -> std::io::Result<String> {
    let dir = "target/results";
    std::fs::create_dir_all(dir)?;
    let path = format!("{dir}/{name}.json");
    let j = Json::arr(cdfs.iter().map(|(variant, pts)| {
        Json::obj(vec![
            ("variant", Json::str(variant)),
            (
                "cdf",
                Json::arr(pts.iter().map(|(v, f)| {
                    Json::arr([Json::num(*v as f64), Json::num(*f)])
                })),
            ),
        ])
    }));
    std::fs::write(&path, j.to_string_pretty())?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_scale() -> Scale {
        Scale { reps: 1, duration_us: 1_500_000, warmup_us: 300_000, n: 5 }
    }

    #[test]
    fn fig4_points_have_all_variants() {
        let pts = fig4(tiny_scale(), &[500.0]);
        assert_eq!(pts.len(), Variant::ALL.len());
        let variants: Vec<&str> = pts.iter().map(|p| p.variant).collect();
        for v in Variant::ALL {
            assert!(variants.contains(&v.name()), "missing {v:?}");
        }
        for p in &pts {
            assert!(p.throughput > 0.0);
            assert!(p.mean_latency_us > 0.0);
        }
    }

    #[test]
    fn raft_leader_cpu_grows_with_n_below_saturation() {
        // At a fixed sub-saturation rate, the Raft leader's CPU must grow
        // with cluster size (the Fig 6 mechanism). Unthrottled runs would
        // saturate at 100% for every n and hide the slope.
        let cpu_at = |n: usize| {
            let mut cfg = presets::fig56(Variant::Raft, n, 200.0);
            cfg.workload.duration_us = 1_500_000;
            cfg.workload.warmup_us = 300_000;
            run_point("raft", n as f64, &cfg, 1).leader_cpu
        };
        let small = cpu_at(3);
        let big = cpu_at(9);
        assert!(big > small, "leader CPU must grow with n: {small} -> {big}");
    }

    #[test]
    fn fig6_runs_all_sizes() {
        let pts = fig6(tiny_scale(), &[3, 7]);
        assert_eq!(pts.len(), 2 * Variant::ALL.len());
        assert!(pts.iter().all(|p| p.leader_cpu > 0.0));
    }

    #[test]
    fn fig7_cdfs_reach_one() {
        let cdfs = fig7(tiny_scale(), 300.0);
        for (variant, pts) in &cdfs {
            assert!(!pts.is_empty(), "{variant}: empty CDF");
            let last = pts.last().unwrap().1;
            assert!((last - 1.0).abs() < 1e-9, "{variant}: CDF ends at {last}");
        }
    }

    #[test]
    fn json_outputs_written() {
        let pts = fig4(tiny_scale(), &[400.0]);
        let path = write_points_json("test_fig4", &pts).unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.as_arr().unwrap().len(), Variant::ALL.len());
    }
}
