//! Bench: regenerate Fig 4 — mean latency vs request rate, 51 replicas,
//! 100 concurrent clients, Raft vs V1 vs V2 (3 repetitions, mean — §4.1).
//!
//! Run: `cargo bench --bench fig4_throughput_latency [-- --quick]`
//! Output: table on stdout + target/results/fig4.json

use epiraft::harness::{self, Scale};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var_os("EPIRAFT_BENCH_QUICK").is_some();
    let scale = if quick { Scale::quick() } else { Scale::paper() };
    let rates = harness::fig4_default_rates();
    let t = std::time::Instant::now();
    let pts = harness::fig4(scale, &rates);
    harness::print_points(
        "Fig 4 — mean latency vs request rate (51 replicas, 100 clients)",
        "rate",
        &pts,
    );
    match harness::write_points_json("fig4", &pts) {
        Ok(path) => println!("\nwrote {path}"),
        Err(e) => eprintln!("write failed: {e}"),
    }
    // Shape assertions (who wins, by roughly what factor).
    let max_tput = |v: &str| {
        pts.iter().filter(|p| p.variant == v).map(|p| p.throughput).fold(0.0, f64::max)
    };
    let raft = max_tput("raft");
    let v1 = max_tput("v1");
    println!(
        "\nshape check: raft ceiling {:.0} req/s, v1 reaches {:.0} req/s ({:.1}x)",
        raft,
        v1,
        v1 / raft
    );
    println!("total bench time: {:.1}s", t.elapsed().as_secs_f64());
}
