//! Bench: regenerate Fig 5 — per-replica CPU usage vs client request
//! rate, 51 replicas, 10 clients (leader vs follower mean, per variant).
//!
//! Run: `cargo bench --bench fig5_cpu_by_rate [-- --quick]`
//! Output: table on stdout + target/results/fig5.json

use epiraft::harness::{self, Scale};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var_os("EPIRAFT_BENCH_QUICK").is_some();
    let scale = if quick { Scale::quick() } else { Scale::paper() };
    let rates = harness::fig5_default_rates();
    let t = std::time::Instant::now();
    let pts = harness::fig5(scale, &rates);
    harness::print_points(
        "Fig 5 — CPU usage vs client request rate (51 replicas, 10 clients)",
        "rate",
        &pts,
    );
    match harness::write_points_json("fig5", &pts) {
        Ok(path) => println!("\nwrote {path}"),
        Err(e) => eprintln!("write failed: {e}"),
    }
    // Shape: at every matched rate, leader CPU ordering raft >= v1 >= v2.
    for &rate in &rates {
        let cpu = |v: &str| {
            pts.iter()
                .find(|p| p.variant == v && p.x == rate)
                .map(|p| p.leader_cpu)
                .unwrap_or(0.0)
        };
        println!(
            "rate {:>6}: leader cpu raft {:>5.1}%  v1 {:>5.1}%  v2 {:>5.1}%",
            rate,
            cpu("raft") * 100.0,
            cpu("v1") * 100.0,
            cpu("v2") * 100.0
        );
    }
    println!("total bench time: {:.1}s", t.elapsed().as_secs_f64());
}
