//! Micro-benchmarks of the hot paths (criterion-lite; §Perf of
//! EXPERIMENTS.md):
//!
//! * scalar `Merge`/`Update` (every gossip receipt runs these),
//! * native batched fleet step vs the AOT-compiled HLO executable through
//!   PJRT (batch-size crossover),
//! * simulator event-loop throughput (events/s),
//! * supporting structures (permutation round, histogram record).
//!
//! Run: `cargo bench --bench micro_hotpath`

use epiraft::config::Config;
use epiraft::epidemic::{EpidemicState, LogView, Permutation};
use epiraft::harness::{bench, black_box};
use epiraft::raft::Variant;
use epiraft::runtime::{Engine, MergeExecutor};
use epiraft::sim::run_experiment;
use epiraft::util::histogram::Histogram;
use epiraft::util::rng::Xoshiro256;

fn main() {
    let samples = 12;
    println!("== micro_hotpath ==");

    // --- scalar merge/update -----------------------------------------------
    let mut rng = Xoshiro256::seed_from_u64(7);
    let mk_state = |rng: &mut Xoshiro256| {
        let mut s = EpidemicState::new(51);
        s.max_commit = rng.next_below(1000);
        s.next_commit = s.max_commit + 1 + rng.next_below(40);
        for _ in 0..rng.next_below(26) {
            let b = rng.next_below(51) as usize;
            s.bitmap.set(b);
        }
        s
    };
    let states: Vec<EpidemicState> = (0..256).map(|_| mk_state(&mut rng)).collect();
    let mut local = mk_state(&mut rng);
    let mut i = 0;
    let r = bench("scalar merge (51 procs)", samples, || {
        local.merge(black_box(&states[i & 255]));
        i += 1;
    });
    println!("{}", r.report_line());

    let log = LogView { last_index: 500, last_term: 3, current_term: 3 };
    let mut j = 0;
    let mut upd = mk_state(&mut rng);
    let r = bench("scalar update_step (51 procs)", samples, || {
        upd.update_step(black_box(j & 50), 26, log);
        j += 1;
    });
    println!("{}", r.report_line());

    // --- permutation + histogram ------------------------------------------
    let mut perm = Permutation::new(51, 0, &mut rng);
    let r = bench("permutation next_round(F=3)", samples, || {
        black_box(perm.next_round(3));
    });
    println!("{}", r.report_line());

    let mut h = Histogram::default();
    let mut v = 1u64;
    let r = bench("histogram record", samples, || {
        v = v.wrapping_mul(6364136223846793005).wrapping_add(1);
        h.record(v % 1_000_000);
    });
    println!("{}", r.report_line());

    // --- native vs HLO fleet step -------------------------------------------
    match Engine::load("artifacts").and_then(|e| {
        let x = MergeExecutor::from_engine(&e)?;
        Ok((e, x))
    }) {
        Ok((engine, exec)) => {
            let geo = engine.geometry;
            let total_states = geo.b;
            let mut rr = Xoshiro256::seed_from_u64(11);
            let bm: Vec<u32> = (0..total_states * geo.w).map(|_| rr.next_u64() as u32).collect();
            let mc: Vec<u32> = (0..total_states).map(|_| rr.next_below(1000) as u32).collect();
            let nc: Vec<u32> = mc.iter().map(|&x| x + 1 + (rr.next_below(40) as u32)).collect();
            let msgs_bm: Vec<u32> =
                (0..total_states * geo.m * geo.w).map(|_| rr.next_u64() as u32).collect();
            let msgs_mc: Vec<u32> =
                (0..total_states * geo.m).map(|_| rr.next_below(1000) as u32).collect();
            let msgs_nc: Vec<u32> =
                msgs_mc.iter().map(|&x| x + 1 + (rr.next_below(40) as u32)).collect();
            let count: Vec<u32> =
                (0..total_states).map(|_| rr.next_below(geo.m as u64 + 1) as u32).collect();
            let me: Vec<u32> = (0..total_states).map(|_| rr.next_below(51) as u32).collect();
            let last_index: Vec<u32> =
                (0..total_states).map(|_| rr.next_below(1100) as u32).collect();
            let last_eq: Vec<u32> = (0..total_states).map(|_| rr.next_below(2) as u32).collect();

            let states_per_call = geo.b as f64;
            let msgs_per_call = (geo.b * geo.m) as f64;

            let r = bench(
                &format!("native fleet step (B={} M={})", geo.b, geo.m),
                samples,
                || {
                    black_box(exec.native_cluster_step(
                        &bm, &mc, &nc, &msgs_bm, &msgs_mc, &msgs_nc, &count, &me, 26,
                        &last_index, &last_eq,
                    ));
                },
            );
            println!(
                "{}   ({:.1}M merges/s)",
                r.report_line(),
                msgs_per_call / r.ns_per_iter.mean * 1e3
            );

            let r = bench(
                &format!("HLO/PJRT fleet step (B={} M={})", geo.b, geo.m),
                samples,
                || {
                    black_box(
                        exec.hlo_cluster_step(
                            &bm, &mc, &nc, &msgs_bm, &msgs_mc, &msgs_nc, &count, &me, 26,
                            &last_index, &last_eq,
                        )
                        .expect("hlo exec"),
                    );
                },
            );
            println!(
                "{}   ({:.2}M merges/s, {:.0} states/call)",
                r.report_line(),
                msgs_per_call / r.ns_per_iter.mean * 1e3,
                states_per_call
            );
        }
        Err(e) => println!("(HLO bench skipped: {e}; run `make artifacts`)"),
    }

    // --- HLO geometry sweep (dispatch amortisation) -------------------------
    for dir in ["artifacts", "artifacts/b256", "artifacts/b1024"] {
        let Ok(engine) = Engine::load(dir) else { continue };
        let Ok(exec) = MergeExecutor::from_engine(&engine) else { continue };
        let geo = engine.geometry;
        let mut rr = Xoshiro256::seed_from_u64(13);
        let bm: Vec<u32> = (0..geo.b * geo.w).map(|_| rr.next_u64() as u32).collect();
        let mc: Vec<u32> = (0..geo.b).map(|_| rr.next_below(1000) as u32).collect();
        let nc: Vec<u32> = mc.iter().map(|&x| x + 1 + (rr.next_below(40) as u32)).collect();
        let msgs_bm: Vec<u32> = (0..geo.b * geo.m * geo.w).map(|_| rr.next_u64() as u32).collect();
        let msgs_mc: Vec<u32> = (0..geo.b * geo.m).map(|_| rr.next_below(1000) as u32).collect();
        let msgs_nc: Vec<u32> = msgs_mc.iter().map(|&x| x + 1 + (rr.next_below(40) as u32)).collect();
        let count: Vec<u32> = (0..geo.b).map(|_| geo.m as u32).collect();
        let me: Vec<u32> = (0..geo.b).map(|_| rr.next_below(51) as u32).collect();
        let last_index: Vec<u32> = (0..geo.b).map(|_| rr.next_below(1100) as u32).collect();
        let last_eq: Vec<u32> = (0..geo.b).map(|_| rr.next_below(2) as u32).collect();
        let merges = (geo.b * geo.m) as f64;
        let r = bench(&format!("HLO fleet step {dir} (B={})", geo.b), 8, || {
            black_box(
                exec.hlo_cluster_step(&bm, &mc, &nc, &msgs_bm, &msgs_mc, &msgs_nc, &count,
                    &me, 26, &last_index, &last_eq).expect("exec"),
            );
        });
        println!("{}   ({:.2}M merges/s)", r.report_line(), merges / r.ns_per_iter.mean * 1e3);
        let r = bench(&format!("native fleet step {dir} (B={})", geo.b), 8, || {
            black_box(exec.native_cluster_step(&bm, &mc, &nc, &msgs_bm, &msgs_mc, &msgs_nc,
                &count, &me, 26, &last_index, &last_eq));
        });
        println!("{}   ({:.2}M merges/s)", r.report_line(), merges / r.ns_per_iter.mean * 1e3);
    }

    // --- simulator event loop -----------------------------------------------
    for variant in Variant::ALL {
        let mut cfg = Config::default();
        cfg.protocol.n = 51;
        cfg.protocol.variant = variant;
        cfg.workload.clients = 100;
        cfg.workload.rate = 800.0;
        cfg.workload.duration_us = 2_000_000;
        cfg.workload.warmup_us = 200_000;
        cfg.seed = 5;
        let report = run_experiment(&cfg);
        println!(
            "sim event loop [{:<4}]: {:>9} events in {:>6.2}s host = {:>10.0} events/s",
            variant.name(),
            report.events_processed,
            report.host_secs,
            report.events_processed as f64 / report.host_secs.max(1e-9)
        );
    }
}
