//! Bench: regenerate Fig 6 — per-replica CPU usage vs number of replicas,
//! 10 closed-loop clients ("enviam pedidos imediatamente após receberem as
//! respostas", §4.2), leader vs followers, per variant.
//!
//! Run: `cargo bench --bench fig6_cpu_by_replicas [-- --quick]`
//! Output: table on stdout + target/results/fig6.json

use epiraft::harness::{self, Scale};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var_os("EPIRAFT_BENCH_QUICK").is_some();
    let scale = if quick { Scale::quick() } else { Scale::paper() };
    let ns = harness::fig6_default_ns();
    let t = std::time::Instant::now();
    let pts = harness::fig6(scale, &ns);
    harness::print_points(
        "Fig 6 — CPU usage vs number of replicas (10 closed-loop clients)",
        "n",
        &pts,
    );
    match harness::write_points_json("fig6", &pts) {
        Ok(path) => println!("\nwrote {path}"),
        Err(e) => eprintln!("write failed: {e}"),
    }
    // Companion series at a fixed sub-saturation rate: shows the paper's
    // rising-leader-CPU-with-n curves directly (the unthrottled loop pins
    // saturated leaders at 100%).
    let fixed = epiraft::harness::figures::fig6_rate(scale, &ns, 150.0);
    harness::print_points(
        "Fig 6b — CPU usage vs number of replicas (fixed 150 req/s)",
        "n",
        &fixed,
    );
    match harness::write_points_json("fig6_fixed_rate", &fixed) {
        Ok(path) => println!("\nwrote {path}"),
        Err(e) => eprintln!("write failed: {e}"),
    }
    // Shape: raft leader CPU grows with n and dominates followers; the V2
    // leader stays near its followers at every size ("em nenhum ponto o
    // gargalo").
    for &n in &ns {
        let p = |v: &str| pts.iter().find(|p| p.variant == v && p.x == n as f64).unwrap();
        println!(
            "n={:>3}: raft leader/follower {:>5.1}%/{:>4.1}%   v1 {:>5.1}%/{:>4.1}%   v2 {:>5.1}%/{:>4.1}%",
            n,
            p("raft").leader_cpu * 100.0,
            p("raft").follower_cpu_mean * 100.0,
            p("v1").leader_cpu * 100.0,
            p("v1").follower_cpu_mean * 100.0,
            p("v2").leader_cpu * 100.0,
            p("v2").follower_cpu_mean * 100.0,
        );
    }
    println!("total bench time: {:.1}s", t.elapsed().as_secs_f64());
}
