//! Bench: regenerate Fig 7 — CDF of the interval between a request being
//! received by the leader and its commit at each replica, 51 replicas,
//! loaded system, per variant.
//!
//! Run: `cargo bench --bench fig7_commit_cdf [-- --quick]`
//! Output: CDF quantiles on stdout + target/results/fig7.json

use epiraft::harness::{self, Scale};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var_os("EPIRAFT_BENCH_QUICK").is_some();
    let scale = if quick { Scale::quick() } else { Scale::paper() };
    let rate = 2000.0; // beyond V1's knee: the paper measures a loaded system (Fig 7 x-axis reaches seconds)
    let t = std::time::Instant::now();
    let cdfs = harness::fig7(scale, rate);
    println!("== Fig 7 — FDA (CDF) leader-receive -> replica-commit, rate {rate} ==");
    for (variant, pts) in &cdfs {
        println!("\n[{variant}] {} committed-entry observations", pts.len());
        for frac in [0.1, 0.25, 0.5, 0.75, 0.9, 0.99] {
            if let Some((v, f)) = pts.iter().find(|(_, f)| *f >= frac) {
                println!("  p{:<4} {:>10} us (cdf {:.3})", (frac * 100.0) as u32, v, f);
            }
        }
    }
    match harness::write_cdfs_json("fig7", &cdfs) {
        Ok(path) => println!("\nwrote {path}"),
        Err(e) => eprintln!("write failed: {e}"),
    }
    // Shape: V2's CDF rises earliest (decentralised commit); original Raft
    // latest (followers wait on the leader's next broadcast).
    let p50 = |name: &str| {
        cdfs.iter()
            .find(|(v, _)| *v == name)
            .and_then(|(_, pts)| pts.iter().find(|(_, f)| *f >= 0.5))
            .map(|(v, _)| *v)
            .unwrap_or(0)
    };
    println!(
        "\nshape check p50: raft {} us, v1 {} us, v2 {} us",
        p50("raft"),
        p50("v1"),
        p50("v2")
    );
    println!("total bench time: {:.1}s", t.elapsed().as_secs_f64());
}
