//! Native ≡ HLO equivalence on randomly generated batches (beyond the
//! golden vectors baked by aot.py): the PJRT-executed `cluster_step`
//! artifact must agree bit-for-bit with the native Rust implementation.
//!
//! Requires `make artifacts` and a build with `--features xla`; tests skip
//! (with a note) when the artifacts directory is absent or the PJRT
//! runtime is unavailable, so `cargo test` stays green in a fresh
//! checkout and in the default (offline, feature-less) build.

use epiraft::prop::{forall, Gen};
use epiraft::runtime::{Engine, MergeExecutor};

fn artifacts_dir() -> Option<String> {
    for dir in ["artifacts", "../artifacts"] {
        if std::path::Path::new(dir).join("meta.json").exists() {
            return Some(dir.to_string());
        }
    }
    None
}

fn random_batch(g: &mut Gen, b: usize, m: usize, w: usize, n_procs: usize) -> Batch {
    let mask = |g: &mut Gen, wi: usize| -> u32 {
        let lo = wi * 32;
        let bits = n_procs.saturating_sub(lo).min(32);
        if bits == 0 {
            0
        } else {
            let full = g.u64_in(0, 1 << 32) as u32;
            if bits == 32 {
                full
            } else {
                full & ((1u32 << bits) - 1)
            }
        }
    };
    let mut bm = Vec::with_capacity(b * w);
    let mut msgs_bm = Vec::with_capacity(b * m * w);
    for _ in 0..b {
        for wi in 0..w {
            bm.push(mask(g, wi));
        }
    }
    for _ in 0..(b * m) {
        for wi in 0..w {
            msgs_bm.push(mask(g, wi));
        }
    }
    let mc: Vec<u32> = (0..b).map(|_| g.u64_in(0, 1000) as u32).collect();
    let nc: Vec<u32> = mc.iter().map(|&x| x + g.u64_in(1, 50) as u32).collect();
    let msgs_mc: Vec<u32> = (0..b * m).map(|_| g.u64_in(0, 1000) as u32).collect();
    let msgs_nc: Vec<u32> = msgs_mc.iter().map(|&x| x + g.u64_in(1, 50) as u32).collect();
    Batch {
        bm,
        mc,
        nc,
        msgs_bm,
        msgs_mc,
        msgs_nc,
        count: (0..b).map(|_| g.u64_in(0, m as u64 + 1) as u32).collect(),
        me: (0..b).map(|_| g.u64_in(0, n_procs as u64) as u32).collect(),
        majority: (n_procs / 2 + 1) as u32,
        last_index: (0..b).map(|_| g.u64_in(0, 1100) as u32).collect(),
        last_term_eq: (0..b).map(|_| g.u64_in(0, 2) as u32).collect(),
    }
}

struct Batch {
    bm: Vec<u32>,
    mc: Vec<u32>,
    nc: Vec<u32>,
    msgs_bm: Vec<u32>,
    msgs_mc: Vec<u32>,
    msgs_nc: Vec<u32>,
    count: Vec<u32>,
    me: Vec<u32>,
    majority: u32,
    last_index: Vec<u32>,
    last_term_eq: Vec<u32>,
}

#[test]
fn hlo_cluster_step_matches_native_on_random_batches() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    };
    let engine = match Engine::load(&dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping: HLO runtime unavailable ({e})");
            return;
        }
    };
    let exec = MergeExecutor::from_engine(&engine).expect("executor");
    let geo = engine.geometry;
    forall("hlo == native cluster_step", 10, |g| {
        let batch = random_batch(g, geo.b, geo.m, geo.w, 51);
        let hlo = exec
            .hlo_cluster_step(
                &batch.bm,
                &batch.mc,
                &batch.nc,
                &batch.msgs_bm,
                &batch.msgs_mc,
                &batch.msgs_nc,
                &batch.count,
                &batch.me,
                batch.majority,
                &batch.last_index,
                &batch.last_term_eq,
            )
            .expect("hlo exec");
        let native = exec.native_cluster_step(
            &batch.bm,
            &batch.mc,
            &batch.nc,
            &batch.msgs_bm,
            &batch.msgs_mc,
            &batch.msgs_nc,
            &batch.count,
            &batch.me,
            batch.majority,
            &batch.last_index,
            &batch.last_term_eq,
        );
        assert_eq!(hlo.0, native.0, "bitmap mismatch");
        assert_eq!(hlo.1, native.1, "max_commit mismatch");
        assert_eq!(hlo.2, native.2, "next_commit mismatch");
    });
}

#[test]
fn golden_vectors_pass() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    };
    if let Err(e) = epiraft::runtime::artifacts_check(&dir) {
        if e.contains("without the `xla` feature") {
            eprintln!("skipping: HLO runtime unavailable ({e})");
            return;
        }
        panic!("artifacts-check failed: {e}");
    }
}

#[test]
fn fleet_state_roundtrip_through_hlo() {
    use epiraft::epidemic::EpidemicState;
    use epiraft::runtime::FleetState;
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    };
    let engine = match Engine::load(&dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping: HLO runtime unavailable ({e})");
            return;
        }
    };
    let exec = MergeExecutor::from_engine(&engine).expect("executor");
    let geo = engine.geometry;

    // A realistic scenario: 26 of 51 replicas voted for index 5.
    let n = 51;
    let mut state = EpidemicState::new(n);
    state.max_commit = 4;
    state.next_commit = 5;
    for i in 0..25 {
        state.bitmap.set(i);
    }
    // One incoming message carries the 26th vote.
    let mut msg = EpidemicState::new(n);
    msg.max_commit = 4;
    msg.next_commit = 5;
    msg.bitmap.set(30);

    let f = FleetState::pack(&[state.clone()], geo);
    let mut msgs_bm = vec![0u32; geo.b * geo.m * geo.w];
    let mut msgs_mc = vec![0u32; geo.b * geo.m];
    let mut msgs_nc = vec![1u32; geo.b * geo.m];
    msgs_bm[..geo.w].copy_from_slice(msg.bitmap.words());
    msgs_mc[0] = msg.max_commit as u32;
    msgs_nc[0] = msg.next_commit as u32;
    let mut count = vec![0u32; geo.b];
    count[0] = 1;
    let me = vec![0u32; geo.b];
    let last_index = vec![8u32; geo.b];
    let last_term_eq = vec![1u32; geo.b];

    let (bm, mc, nc) = exec
        .hlo_cluster_step(
            &f.bm, &f.mc, &f.nc, &msgs_bm, &msgs_mc, &msgs_nc, &count, &me,
            26, &last_index, &last_term_eq,
        )
        .expect("exec");
    let out = FleetState { bm, mc, nc }.unpack_row(0, geo, n);
    // 25 + 1 = 26 votes = majority: commit advances to 5, vote moves to the
    // log end (8), own bit re-set.
    assert_eq!(out.max_commit, 5);
    assert_eq!(out.next_commit, 8);
    assert!(out.bitmap.get(0));
    assert_eq!(out.bitmap.count(), 1);
}
