//! End-to-end integration tests over the simulator: the paper's
//! qualitative claims at reduced scale, protocol interop across repair and
//! election paths, and metric plumbing.

use epiraft::config::{presets, Config};
use epiraft::raft::Variant;
use epiraft::sim::{run_experiment, run_with_faults, Fault, FaultSchedule};

fn base_cfg(n: usize, variant: Variant) -> Config {
    let mut cfg = Config::default();
    cfg.protocol.n = n;
    cfg.protocol.variant = variant;
    cfg.workload.clients = 20;
    cfg.workload.duration_us = 4_000_000;
    cfg.workload.warmup_us = 800_000;
    cfg.seed = 20230713;
    cfg
}

/// §4.2 / Fig 4: both extensions outperform original Raft at scale.
#[test]
fn extensions_beat_raft_throughput_at_scale() {
    let mut raft = base_cfg(25, Variant::Raft);
    let mut v1 = base_cfg(25, Variant::V1);
    let mut v2 = base_cfg(25, Variant::V2);
    for c in [&mut raft, &mut v1, &mut v2] {
        c.workload.clients = 50;
    }
    let r_raft = run_experiment(&raft);
    let r_v1 = run_experiment(&v1);
    let r_v2 = run_experiment(&v2);
    assert!(
        r_v1.throughput > 2.0 * r_raft.throughput,
        "v1 {} vs raft {}",
        r_v1.throughput,
        r_raft.throughput
    );
    assert!(
        r_v2.throughput > 2.0 * r_raft.throughput,
        "v2 {} vs raft {}",
        r_v2.throughput,
        r_raft.throughput
    );
}

/// §4.2 / Fig 5-6: leader CPU ordering — raft >> v1 > v2 ≈ followers.
#[test]
fn leader_cpu_ordering_matches_paper() {
    let rate = 150.0;
    let cpu = |variant| {
        let mut cfg = base_cfg(25, variant);
        cfg.workload.clients = 10;
        cfg.workload.rate = rate;
        run_experiment(&cfg)
    };
    let raft = cpu(Variant::Raft);
    let v1 = cpu(Variant::V1);
    let v2 = cpu(Variant::V2);
    assert!(
        raft.leader_cpu > v1.leader_cpu,
        "raft {} !> v1 {}",
        raft.leader_cpu,
        v1.leader_cpu
    );
    assert!(v1.leader_cpu > v2.leader_cpu, "v1 {} !> v2 {}", v1.leader_cpu, v2.leader_cpu);
    // V2's leader is only slightly above its followers (paper: "uso da CPU
    // ligeiramente superior aos seguidores").
    assert!(
        v2.leader_cpu < v2.follower_cpu_mean * 2.0 + 0.30,
        "v2 leader {} vs followers {}",
        v2.leader_cpu,
        v2.follower_cpu_mean
    );
    // Original Raft is "altamente centralizado no líder".
    assert!(
        raft.leader_cpu > raft.follower_cpu_mean * 4.0,
        "raft leader {} vs followers {}",
        raft.leader_cpu,
        raft.follower_cpu_mean
    );
}

/// Fig 6 mechanism: raft leader CPU grows with n; v2 leader CPU stays flat.
#[test]
fn leader_cpu_scaling_with_replicas() {
    let rate = 120.0;
    let run = |variant, n| {
        let mut cfg = base_cfg(n, variant);
        cfg.workload.clients = 10;
        cfg.workload.rate = rate;
        run_experiment(&cfg)
    };
    let raft_small = run(Variant::Raft, 5);
    let raft_big = run(Variant::Raft, 31);
    assert!(
        raft_big.leader_cpu > raft_small.leader_cpu * 2.0,
        "raft leader CPU must grow strongly with n: {} -> {}",
        raft_small.leader_cpu,
        raft_big.leader_cpu
    );
    let v2_small = run(Variant::V2, 5);
    let v2_big = run(Variant::V2, 31);
    assert!(
        v2_big.leader_cpu < v2_small.leader_cpu * 2.0,
        "v2 leader CPU must stay near-flat with n: {} -> {}",
        v2_small.leader_cpu,
        v2_big.leader_cpu
    );
}

/// Fig 7 mechanism: V2 followers learn commits without waiting for the
/// leader's next round; Raft followers wait for the heartbeat carrying
/// leader_commit.
#[test]
fn v2_followers_commit_faster_than_raft() {
    // Fig 7's setting: 51 replicas under load. Original Raft is saturated
    // (its ceiling at n=51 is ~125 req/s), so followers learn the commit
    // index only when the queued next broadcast finally reaches them —
    // hundreds of ms. V2 followers advance CommitIndex from the gossiped
    // structures at gossip-hop scale without waiting for the leader.
    let mut raft = base_cfg(51, Variant::Raft);
    let mut v2 = base_cfg(51, Variant::V2);
    for c in [&mut raft, &mut v2] {
        c.workload.clients = 100;
        c.workload.rate = 300.0;
    }
    let r = run_experiment(&raft);
    let v = run_experiment(&v2);
    assert!(r.commit_interval.count() > 0 && v.commit_interval.count() > 0);
    assert!(
        (v.commit_interval.p50() as f64) < (r.commit_interval.p50() as f64) / 2.0,
        "v2 follower commit p50 {} must clearly beat saturated raft {}",
        v.commit_interval.p50(),
        r.commit_interval.p50()
    );
}

/// Repair path: a follower partitioned away catches up after healing.
#[test]
fn partitioned_follower_catches_up() {
    for variant in Variant::ALL {
        let mut cfg = base_cfg(5, variant);
        cfg.workload.duration_us = 6_000_000;
        // Cut replica 4 off from everyone for 2 simulated seconds.
        let faults = FaultSchedule::new(vec![
            Fault::Partition { at: 1_000_000, groups: vec![0, 0, 0, 0, 1] },
            Fault::Heal { at: 3_000_000 },
        ]);
        let report = run_with_faults(&cfg, faults);
        assert!(report.safety_ok, "{variant:?}");
        assert!(report.completed > 0, "{variant:?}");
        // All replicas end close to the max commit (the cut replica was
        // repaired after healing).
        let min_cpu_nonzero = report.cpu.iter().all(|&c| c > 0.0);
        assert!(min_cpu_nonzero, "{variant:?}: every replica did work");
    }
}

/// Loss bursts mid-run: gossip keeps replicating (the paper's robustness
/// motivation for epidemic dissemination).
#[test]
fn gossip_progress_under_loss_burst() {
    for variant in [Variant::V1, Variant::V2] {
        let mut cfg = base_cfg(9, variant);
        cfg.workload.duration_us = 5_000_000;
        let faults = FaultSchedule::new(vec![
            Fault::SetLoss { at: 1_000_000, loss: 0.25 },
            Fault::SetLoss { at: 3_000_000, loss: 0.0 },
        ]);
        let report = run_with_faults(&cfg, faults);
        assert!(report.safety_ok, "{variant:?}");
        assert!(
            report.max_commit > 100,
            "{variant:?}: commit stalled under loss burst ({})",
            report.max_commit
        );
    }
}

/// The presets module reproduces the paper's §4.1 setups.
#[test]
fn presets_shapes() {
    let cfg = presets::fig4(Variant::V1, 2000.0);
    assert_eq!(cfg.protocol.n, 51);
    assert_eq!(cfg.workload.clients, 100);
    assert_eq!(cfg.workload.rate, 2000.0);
    let cfg = presets::fig56(Variant::V2, 21, 0.0);
    assert_eq!(cfg.protocol.n, 21);
    assert_eq!(cfg.workload.clients, 10);
}

/// Deep determinism: full reports identical for identical seeds at scale.
#[test]
fn full_run_determinism_at_scale() {
    let mut cfg = base_cfg(21, Variant::V2);
    cfg.workload.duration_us = 2_000_000;
    let a = run_experiment(&cfg);
    let b = run_experiment(&cfg);
    assert_eq!(a.completed, b.completed);
    assert_eq!(a.messages, b.messages);
    assert_eq!(a.max_commit, b.max_commit);
    assert_eq!(a.cpu, b.cpu);
}

/// Ablation flag: re-enabling V2 success responses increases leader work.
#[test]
fn v2_success_responses_cost_leader_cpu() {
    let mut off = base_cfg(25, Variant::V2);
    off.workload.clients = 10;
    off.workload.rate = 300.0;
    let mut on = off.clone();
    on.protocol.v2_success_responses = true;
    let r_off = run_experiment(&off);
    let r_on = run_experiment(&on);
    assert!(
        r_on.leader_cpu > r_off.leader_cpu,
        "ack-on {} must exceed ack-off {}",
        r_on.leader_cpu,
        r_off.leader_cpu
    );
}

/// Raft coalescing ablation (A2b). Finding: a batching window recovers
/// most of classic Raft's throughput ceiling at saturation — a large part
/// of V1's Fig 4 advantage over *per-request* Paxi Raft is batching. What
/// batching does NOT fix is the leader-centric CPU profile at moderate
/// load (Figs 5/6): the coalesced leader still pays O(n) sends+replies per
/// window, so its CPU stays far above V2's.
#[test]
fn raft_coalescing_helps_but_leader_cpu_still_centralised() {
    let mut plain = base_cfg(51, Variant::Raft);
    plain.workload.clients = 100;
    let mut coalesced = plain.clone();
    coalesced.protocol.raft_coalesce_us = 5_000;
    let r_plain = run_experiment(&plain);
    let r_coal = run_experiment(&coalesced);
    assert!(
        r_coal.throughput > 3.0 * r_plain.throughput,
        "coalescing must lift the ceiling substantially: {} vs {}",
        r_coal.throughput,
        r_plain.throughput
    );
    // At a moderate matched rate, V2's leader stays far cheaper than even
    // the coalesced-Raft leader.
    let mut coal_mid = base_cfg(51, Variant::Raft);
    coal_mid.protocol.raft_coalesce_us = 5_000;
    coal_mid.workload.clients = 10;
    coal_mid.workload.rate = 150.0;
    let mut v2_mid = base_cfg(51, Variant::V2);
    v2_mid.workload.clients = 10;
    v2_mid.workload.rate = 150.0;
    let r_coal_mid = run_experiment(&coal_mid);
    let r_v2_mid = run_experiment(&v2_mid);
    assert!(
        r_v2_mid.leader_cpu < r_coal_mid.leader_cpu * 0.6,
        "v2 leader {} must undercut coalesced-raft leader {}",
        r_v2_mid.leader_cpu,
        r_coal_mid.leader_cpu
    );
}
