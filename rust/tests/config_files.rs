//! The shipped scenario configs (`configs/*.toml`) must parse, validate,
//! and actually run — they are part of the public interface.

use epiraft::config::Config;
use epiraft::sim::run_experiment;

fn load(name: &str) -> Config {
    let path = format!("configs/{name}.toml");
    Config::from_file(&path).unwrap_or_else(|e| panic!("{path}: {e}"))
}

#[test]
fn all_shipped_configs_parse_and_validate() {
    for name in ["paper51", "lan", "wan", "lossy", "pull"] {
        let cfg = load(name);
        cfg.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
    }
}

#[test]
fn pull_config_selects_the_pull_strategy_and_runs() {
    let mut cfg = load("pull");
    assert_eq!(cfg.protocol.variant, epiraft::raft::Variant::Pull);
    assert_eq!(cfg.protocol.fanout, 1, "seed fanout is the preset's point");
    // Shrink for test time.
    cfg.protocol.n = 7;
    cfg.workload.clients = 5;
    cfg.workload.duration_us = 2_000_000;
    cfg.workload.warmup_us = 400_000;
    let report = run_experiment(&cfg);
    assert!(report.safety_ok);
    assert!(report.completed > 0, "pull preset must serve requests");
    assert_eq!(report.variant, "pull");
}

#[test]
fn paper51_matches_the_papers_setup() {
    let cfg = load("paper51");
    assert_eq!(cfg.protocol.n, 51);
    assert_eq!(cfg.workload.clients, 100);
    assert_eq!(cfg.seed, 20230713);
}

#[test]
fn wan_config_slows_timeouts_consistently() {
    let cfg = load("wan");
    assert!(cfg.network.latency_mean_us >= 10_000.0);
    assert!(
        cfg.protocol.election_timeout_min_us > cfg.protocol.heartbeat_interval_us,
        "WAN timeouts must stay consistent"
    );
}

#[test]
fn lossy_config_runs_and_stays_safe() {
    let mut cfg = load("lossy");
    // Shrink for test time.
    cfg.workload.duration_us = 2_000_000;
    cfg.workload.warmup_us = 400_000;
    let report = run_experiment(&cfg);
    assert!(report.safety_ok);
    assert!(report.completed > 0, "progress under 10% loss");
}

#[test]
fn lan_config_runs_quickly() {
    let mut cfg = load("lan");
    cfg.protocol.n = 11; // shrink for test time
    cfg.workload.duration_us = 1_500_000;
    cfg.workload.warmup_us = 300_000;
    let report = run_experiment(&cfg);
    assert!(report.safety_ok);
    assert!(report.throughput > 0.0);
}
