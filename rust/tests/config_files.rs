//! The shipped scenario configs (`configs/*.toml`) must parse, validate,
//! and actually run — they are part of the public interface.

use epiraft::config::Config;
use epiraft::sim::run_experiment;

fn load(name: &str) -> Config {
    let path = format!("configs/{name}.toml");
    Config::from_file(&path).unwrap_or_else(|e| panic!("{path}: {e}"))
}

#[test]
fn all_shipped_configs_parse_and_validate() {
    let names = [
        "paper51",
        "lan",
        "wan",
        "lossy",
        "pull",
        "adaptive",
        "lossy-burst",
        "unreliable",
        "live-tcp",
        "open-loop",
        "durable",
        "queueing",
    ];
    for name in names {
        let cfg = load(name);
        cfg.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
    }
}

#[test]
fn live_tcp_config_pins_the_socket_transport_and_peer_table() {
    use epiraft::config::TransportKind;
    let cfg = load("live-tcp");
    assert_eq!(cfg.cluster.transport, TransportKind::Tcp, "the preset's point is TCP");
    assert_eq!(cfg.protocol.n, 5);
    for id in 0..5 {
        let addr = cfg.cluster.peer_addr(id).unwrap_or_else(|| panic!("peer {id} missing"));
        assert!(addr.starts_with("127.0.0.1:"), "loopback preset, got {addr}");
    }
    // Each `--node-id` invocation of the recipe must validate too.
    for id in 0..5 {
        let mut cfg = load("live-tcp");
        cfg.set("cluster.node_id", &id.to_string()).unwrap();
        cfg.validate().unwrap_or_else(|e| panic!("node {id}: {e}"));
    }
}

#[test]
fn adaptive_config_enables_the_controller_and_runs() {
    let mut cfg = load("adaptive");
    assert_eq!(cfg.protocol.variant, epiraft::raft::Variant::Pull);
    assert!(cfg.protocol.adaptive.enabled, "the preset's point is the controller");
    assert_eq!(cfg.protocol.adaptive.fanout_min, 1);
    assert_eq!(cfg.protocol.adaptive.fanout_max, 8);
    assert_eq!(cfg.protocol.adaptive.gain, 1.0);
    assert_eq!(cfg.protocol.adaptive.backoff, 0.8);
    // Shrink for test time.
    cfg.protocol.n = 9;
    cfg.workload.clients = 5;
    cfg.workload.duration_us = 2_000_000;
    cfg.workload.warmup_us = 400_000;
    let report = run_experiment(&cfg);
    assert!(report.safety_ok);
    assert!(report.completed > 0, "adaptive preset must serve requests");
    assert!(report.fanout_current >= 1, "leader must have planned adaptive rounds");
}

#[test]
fn open_loop_config_sets_the_arrival_model_and_runs() {
    use epiraft::config::{ArrivalModel, KeyDist};
    let mut cfg = load("open-loop");
    assert_eq!(cfg.workload.arrival, ArrivalModel::Open, "the preset's point is open loop");
    assert_eq!(cfg.workload.max_inflight, 32);
    assert_eq!(cfg.workload.key_dist, KeyDist::Zipfian);
    assert_eq!(cfg.workload.zipf_theta, 0.99);
    assert!(cfg.protocol.batch.enabled, "group commit rides along");
    assert_eq!(cfg.protocol.batch.max_entries, 64);
    assert_eq!(cfg.protocol.batch.max_bytes, 1_048_576);
    assert_eq!(cfg.protocol.batch.flush_us, 20_000);
    // The preset must survive a dump/set round trip: every key it sets is
    // a key `config-dump` emits and `Config::set` accepts.
    let mut rebuilt = epiraft::config::Config::default();
    for (k, v) in epiraft::config::dump(&cfg) {
        rebuilt.set(&k, &v).unwrap_or_else(|e| panic!("{k}={v}: {e}"));
    }
    rebuilt.validate().unwrap();
    assert_eq!(rebuilt.workload.arrival, ArrivalModel::Open);
    assert_eq!(rebuilt.workload.key_dist, KeyDist::Zipfian);
    assert!(rebuilt.protocol.batch.enabled);
    // Shrink for test time.
    cfg.protocol.n = 9;
    cfg.workload.duration_us = 2_000_000;
    cfg.workload.warmup_us = 400_000;
    let report = run_experiment(&cfg);
    assert!(report.safety_ok);
    assert!(report.completed > 0, "open-loop preset must serve requests");
    // rate 2000 against a 9-replica leader leaves headroom, so shedding is
    // load-dependent; the invariant is that the counter is plumbed, which
    // sim::workload's own tests pin. Validation must also reject the model
    // without a rate.
    let mut cfg = load("open-loop");
    cfg.set("workload.rate", "0").unwrap();
    assert!(cfg.validate().is_err(), "open arrival without a rate must fail validation");
}

#[test]
fn unreliable_config_demotes_its_slow_replicas_and_runs() {
    let mut cfg = load("unreliable");
    assert_eq!(cfg.protocol.variant, epiraft::raft::Variant::Pull);
    assert!(cfg.protocol.unreliable.enabled, "the preset's point is the demotion policy");
    assert_eq!(cfg.network.links.len(), 2, "two permanently-slow replicas");
    // Shrink for test time (keep the slow ids inside the cluster).
    cfg.protocol.n = 9;
    cfg.network.links.clear();
    cfg.set("sim.links.8", "200000").unwrap();
    cfg.workload.clients = 5;
    cfg.workload.duration_us = 3_000_000;
    cfg.workload.warmup_us = 400_000;
    cfg.validate().unwrap();
    let report = run_experiment(&cfg);
    assert!(report.safety_ok);
    assert!(report.completed > 0, "unreliable preset must serve requests");
    assert!(report.demotions >= 1, "the slow replica must be demoted");
    // The same file with the switch off must validate too (inert knobs).
    let mut cfg = load("unreliable");
    cfg.set("protocol.unreliable.enabled", "false").unwrap();
    cfg.validate().unwrap();
}

#[test]
fn durable_config_pins_the_wal_knobs_and_runs_in_memory() {
    use epiraft::config::FsyncMode;
    let cfg = load("durable");
    assert_eq!(cfg.protocol.storage.dir, "data", "the preset's point is the WAL");
    assert_eq!(cfg.protocol.storage.fsync, FsyncMode::Batch);
    assert_eq!(cfg.protocol.storage.snapshot_interval_entries, 1000);
    assert_eq!(cfg.protocol.storage.retain_entries, 1024);
    assert_eq!(cfg.cluster.kill_node, 2);
    assert_eq!(cfg.cluster.restart_after_us, 500_000);
    assert_eq!(cfg.cost.fsync_us, 200.0);
    // The preset must survive a dump/set round trip: every key it sets is
    // a key `config-dump` emits and `Config::set` accepts.
    let mut rebuilt = epiraft::config::Config::default();
    for (k, v) in epiraft::config::dump(&cfg) {
        rebuilt.set(&k, &v).unwrap_or_else(|e| panic!("{k}={v}: {e}"));
    }
    rebuilt.validate().unwrap();
    assert_eq!(rebuilt.protocol.storage.dir, "data");
    assert_eq!(rebuilt.protocol.storage.fsync, FsyncMode::Batch);
    // Sim run on the same knobs minus the directory: `MemStorage` counts
    // the same virtual barriers and takes the same snapshots without
    // touching a disk (the checkout must stay clean under `cargo test`).
    let mut cfg = load("durable");
    cfg.set("storage.dir", "").unwrap();
    cfg.protocol.n = 9;
    cfg.protocol.storage.snapshot_interval_entries = 50;
    cfg.protocol.storage.retain_entries = 50;
    cfg.workload.clients = 10;
    cfg.workload.duration_us = 2_000_000;
    cfg.workload.warmup_us = 400_000;
    cfg.validate().unwrap();
    let report = run_experiment(&cfg);
    assert!(report.safety_ok);
    assert!(report.completed > 0, "durable preset must serve requests");
    assert!(report.fsyncs > 0, "fsync = batch must count barriers");
    assert!(report.snapshots_taken > 0, "interval 50 must trigger snapshots");
}

#[test]
fn queueing_config_caps_the_leader_nic_and_runs() {
    let mut cfg = load("queueing");
    assert!(cfg.network.bandwidth.enabled(), "the preset's point is the capped NIC");
    assert_eq!(cfg.network.bandwidth.links.len(), 1);
    assert_eq!(cfg.network.bandwidth.links[0].endpoints(cfg.protocol.n).unwrap(), (Some(0), None));
    assert_eq!(cfg.network.bandwidth.links[0].rate, 400_000);
    assert_eq!(cfg.network.bandwidth.max_queue, 0, "byte-bounded, not frame-bounded");
    assert_eq!(cfg.network.bandwidth.max_queue_bytes, 8000);
    // The preset must survive a dump/set round trip: every key it sets is
    // a key `config-dump` emits and `Config::set` accepts.
    let mut rebuilt = epiraft::config::Config::default();
    for (k, v) in epiraft::config::dump(&cfg) {
        rebuilt.set(&k, &v).unwrap_or_else(|e| panic!("{k}={v}: {e}"));
    }
    rebuilt.validate().unwrap();
    assert_eq!(rebuilt.network.bandwidth, cfg.network.bandwidth);
    // Shrink for test time. The capped NIC must show up in the queueing
    // counters while leaving safety and progress intact.
    cfg.protocol.n = 9;
    cfg.workload.clients = 5;
    cfg.workload.duration_us = 2_000_000;
    cfg.workload.warmup_us = 400_000;
    cfg.validate().unwrap();
    let report = run_experiment(&cfg);
    assert!(report.safety_ok);
    assert!(report.completed > 0, "queueing preset must serve requests");
    assert!(report.leader_queue_wait_us > 0, "the capped leader NIC must queue");
}

#[test]
fn adaptive_validation_rejects_bad_windows_and_gains() {
    // The committed preset must sit inside the validated space; the same
    // keys with an inverted window or zero gain must be rejected.
    let mut cfg = load("adaptive");
    cfg.set("protocol.adaptive.fanout_min", "9").unwrap();
    assert!(cfg.validate().is_err(), "fanout_min > fanout_max must fail validation");
    let mut cfg = load("adaptive");
    cfg.set("protocol.adaptive.gain", "0").unwrap();
    assert!(cfg.validate().is_err(), "zero gain must fail validation");
    let mut cfg = load("adaptive");
    cfg.set("protocol.adaptive.backoff", "0").unwrap();
    assert!(cfg.validate().is_err(), "zero backoff must fail validation");
}

#[test]
fn lossy_burst_config_runs_and_stays_safe_fixed_and_adaptive() {
    for adaptive in [false, true] {
        let mut cfg = load("lossy-burst");
        assert!(cfg.network.ge_good_to_bad > 0.0, "burst chain must be on");
        assert!(cfg.network.duplicate > 0.0, "duplication knob must be on");
        cfg.protocol.adaptive.enabled = adaptive;
        // Shrink for test time.
        cfg.protocol.n = 9;
        cfg.workload.duration_us = 2_500_000;
        cfg.workload.warmup_us = 400_000;
        let report = run_experiment(&cfg);
        assert!(report.safety_ok, "adaptive={adaptive}: burst loss broke safety");
        assert!(report.completed > 0, "adaptive={adaptive}: no progress under bursts");
    }
}

#[test]
fn pull_config_selects_the_pull_strategy_and_runs() {
    let mut cfg = load("pull");
    assert_eq!(cfg.protocol.variant, epiraft::raft::Variant::Pull);
    assert_eq!(cfg.protocol.fanout, 1, "seed fanout is the preset's point");
    // Shrink for test time.
    cfg.protocol.n = 7;
    cfg.workload.clients = 5;
    cfg.workload.duration_us = 2_000_000;
    cfg.workload.warmup_us = 400_000;
    let report = run_experiment(&cfg);
    assert!(report.safety_ok);
    assert!(report.completed > 0, "pull preset must serve requests");
    assert_eq!(report.variant, "pull");
}

#[test]
fn paper51_matches_the_papers_setup() {
    let cfg = load("paper51");
    assert_eq!(cfg.protocol.n, 51);
    assert_eq!(cfg.workload.clients, 100);
    assert_eq!(cfg.seed, 20230713);
}

#[test]
fn wan_config_slows_timeouts_consistently() {
    let cfg = load("wan");
    assert!(cfg.network.latency_mean_us >= 10_000.0);
    assert!(
        cfg.protocol.election_timeout_min_us > cfg.protocol.heartbeat_interval_us,
        "WAN timeouts must stay consistent"
    );
}

#[test]
fn lossy_config_runs_and_stays_safe() {
    let mut cfg = load("lossy");
    // Shrink for test time.
    cfg.workload.duration_us = 2_000_000;
    cfg.workload.warmup_us = 400_000;
    let report = run_experiment(&cfg);
    assert!(report.safety_ok);
    assert!(report.completed > 0, "progress under 10% loss");
}

#[test]
fn lan_config_runs_quickly() {
    let mut cfg = load("lan");
    cfg.protocol.n = 11; // shrink for test time
    cfg.workload.duration_us = 1_500_000;
    cfg.workload.warmup_us = 300_000;
    let report = run_experiment(&cfg);
    assert!(report.safety_ok);
    assert!(report.throughput > 0.0);
}
