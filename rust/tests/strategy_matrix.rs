//! The strategy-layer contract: every registered `Variant` must drive the
//! same scenarios to the same safety invariants — committed-prefix
//! agreement (log-prefix consistency) and commit monotonicity — and every
//! gossip variant must fall back to classic-RPC catch-up when a follower
//! misses rounds.
//!
//! Two levels:
//!
//! * simulator matrix — each variant through an identical `run_experiment`
//!   scenario (same seed, same workload);
//! * driver-level harness — a hand-rolled host built on `epiraft::driver`
//!   (the same `NodeInput`/`ActionSink` cycle the simulator and the live
//!   cluster use), recording every `Committed` action to check
//!   monotonicity directly.

use epiraft::config::{Config, ProtocolConfig};
use epiraft::driver::{self, ActionSink, NodeInput};
use epiraft::kvstore::Command;
use epiraft::raft::{Message, Node, NodeId, Variant};
use epiraft::sim::run_experiment;
use std::collections::VecDeque;

// ---------------------------------------------------------------------------
// Simulator matrix: one scenario, every variant, same invariants.
// ---------------------------------------------------------------------------

fn sim_scenario(adaptive: bool, batched: bool) {
    for variant in Variant::ALL {
        let mut cfg = Config::default();
        cfg.protocol.n = 7;
        cfg.protocol.variant = variant;
        cfg.protocol.adaptive.enabled = adaptive;
        if batched {
            // PR 6 group commit: short flush so batches actually cycle at
            // this scenario's rate, small cap so the size trigger fires too.
            cfg.protocol.batch.enabled = true;
            cfg.protocol.batch.flush_us = 500;
            cfg.protocol.batch.max_entries = 16;
        }
        cfg.workload.clients = 10;
        cfg.workload.duration_us = 2_500_000;
        cfg.workload.warmup_us = 300_000;
        cfg.seed = 0xA11CE;
        let report = run_experiment(&cfg);
        let tag = match (adaptive, batched) {
            (true, _) => "adaptive",
            (_, true) => "batched",
            _ => "fixed",
        };
        assert!(report.safety_ok, "{variant:?}/{tag}: committed prefixes diverged");
        assert!(
            report.completed > 50,
            "{variant:?}/{tag}: only {} completed",
            report.completed
        );
        assert_eq!(report.elections, 0, "{variant:?}/{tag}: stable leader deposed");
        assert!(report.max_commit > 0, "{variant:?}/{tag}: nothing committed");
    }
}

#[test]
fn every_variant_passes_the_same_sim_scenario() {
    sim_scenario(false, false);
}

#[test]
fn every_variant_passes_the_same_sim_scenario_with_adaptive_fanout() {
    sim_scenario(true, false);
}

#[test]
fn every_variant_passes_the_same_sim_scenario_with_group_commit() {
    sim_scenario(false, true);
}

// ---------------------------------------------------------------------------
// Driver-level harness with direct commit-monotonicity checks.
// ---------------------------------------------------------------------------

/// Routes sends onto an in-memory wire and records commit ranges.
struct WireSink<'a> {
    inboxes: &'a mut Vec<VecDeque<Message>>,
    commits: &'a mut Vec<Vec<(u64, u64)>>,
}

impl ActionSink for WireSink<'_> {
    fn send(&mut self, _from: NodeId, to: NodeId, msg: Message) {
        self.inboxes[to].push_back(msg);
    }

    fn client_reply(
        &mut self,
        _from: NodeId,
        _req: u64,
        _result: epiraft::raft::ClientResult,
    ) {
    }

    fn committed(&mut self, at: NodeId, _is_leader: bool, from: u64, to: u64) {
        self.commits[at].push((from, to));
    }
}

fn commit_monotonicity_and_prefix_agreement(adaptive: bool) {
    for variant in Variant::ALL {
        let n = 5;
        let mut cfg = ProtocolConfig::for_variant(n, variant);
        cfg.adaptive.enabled = adaptive;
        let mut nodes: Vec<Node> =
            (0..n).map(|i| Node::new(i, cfg.clone(), 0xBEEF + i as u64)).collect();
        let mut inboxes: Vec<VecDeque<Message>> = vec![VecDeque::new(); n];
        let mut commits: Vec<Vec<(u64, u64)>> = vec![Vec::new(); n];

        // Stable-leader bootstrap, actions routed through the shared driver.
        let boot = nodes[0].bootstrap_leader(0);
        for f in nodes.iter_mut().skip(1) {
            f.bootstrap_follower(0, 0);
        }
        {
            let mut sink = WireSink { inboxes: &mut inboxes, commits: &mut commits };
            driver::dispatch(0, true, boot, &mut sink);
        }

        let mut t: u64 = 1;
        let mut next_req: u64 = 1;
        for round in 0..400u32 {
            // Inject a client command at the leader every few iterations.
            if round % 10 == 0 && next_req <= 20 {
                t += 1;
                let mut sink = WireSink { inboxes: &mut inboxes, commits: &mut commits };
                driver::step(
                    &mut nodes[0],
                    t,
                    NodeInput::Client {
                        req: next_req,
                        cmd: Command::Put { key: next_req, value: next_req * 3 },
                    },
                    &mut sink,
                );
                next_req += 1;
            }
            // Deliver at most one queued message per node.
            let mut delivered = false;
            for i in 0..n {
                if let Some(msg) = inboxes[i].pop_front() {
                    delivered = true;
                    t += 1;
                    let mut sink = WireSink { inboxes: &mut inboxes, commits: &mut commits };
                    driver::step(&mut nodes[i], t, NodeInput::Message(msg), &mut sink);
                }
            }
            if !delivered {
                // Wire idle: fire the earliest pending timer (the leader's
                // next round/heartbeat — election timeouts are far larger
                // than the simulated horizon, so the leader stays stable).
                let (i, dl) = (0..n)
                    .map(|i| (i, nodes[i].next_deadline()))
                    .min_by_key(|&(_, dl)| dl)
                    .unwrap();
                t = t.max(dl);
                let mut sink = WireSink { inboxes: &mut inboxes, commits: &mut commits };
                driver::step(&mut nodes[i], t, NodeInput::Tick, &mut sink);
            }
        }

        // Commit monotonicity: per node, ranges are contiguous and increasing.
        for (i, ranges) in commits.iter().enumerate() {
            let mut prev = 0u64;
            for &(from, to) in ranges {
                assert_eq!(
                    from, prev,
                    "{variant:?} node {i}: commit ranges must be contiguous"
                );
                assert!(to > from, "{variant:?} node {i}: commit must advance");
                prev = to;
            }
            assert_eq!(
                prev,
                nodes[i].commit_index(),
                "{variant:?} node {i}: recorded ranges must cover the commit index"
            );
        }

        // Progress: the leader committed every injected request (+ no-op).
        assert_eq!(
            nodes[0].commit_index(),
            21,
            "{variant:?}: leader must commit the full workload"
        );
        assert!(
            (1..n).any(|i| nodes[i].commit_index() > 0),
            "{variant:?}: commits must propagate beyond the leader"
        );

        // Log-prefix consistency: every committed prefix agrees with the
        // most-committed replica.
        let reference = (0..n).max_by_key(|&i| nodes[i].commit_index()).unwrap();
        for i in 0..n {
            for idx in 1..=nodes[i].commit_index() {
                assert_eq!(
                    nodes[i].log().get(idx),
                    nodes[reference].log().get(idx),
                    "{variant:?}: node {i} disagrees on committed index {idx}"
                );
            }
        }
    }
}

#[test]
fn commit_monotonicity_and_prefix_agreement_for_every_variant() {
    commit_monotonicity_and_prefix_agreement(false);
}

#[test]
fn commit_monotonicity_and_prefix_agreement_with_adaptive_fanout() {
    commit_monotonicity_and_prefix_agreement(true);
}

// ---------------------------------------------------------------------------
// Repair path: a follower that misses gossip rounds recovers via classic
// RPC catch-up.
// ---------------------------------------------------------------------------

fn sends_of(actions: &[epiraft::raft::Action]) -> Vec<(usize, Message)> {
    actions
        .iter()
        .filter_map(|a| match a {
            epiraft::raft::Action::Send { to, msg } => Some((*to, msg.clone())),
            _ => None,
        })
        .collect()
}

#[test]
fn follower_missing_rounds_recovers_via_classic_rpc_catch_up() {
    // Pull rides along: its leader *seed* rounds are stamped and batched
    // exactly like V1 rounds, so a follower that missed them NACKs into
    // the same classic-RPC repair path. Each variant runs twice: fixed
    // fanout and with the adaptive controller enabled (clamp window pinned
    // at 2 — the scenario depends on every round targeting both
    // followers).
    let cases = [Variant::V1, Variant::V2, Variant::Pull]
        .into_iter()
        .flat_map(|v| [(v, false), (v, true)]);
    for (variant, adaptive) in cases {
        let mut cfg = ProtocolConfig::for_variant(3, variant);
        cfg.fanout = 2; // every round targets both followers
        if adaptive {
            cfg.adaptive.enabled = true;
            cfg.adaptive.fanout_min = 2;
            cfg.adaptive.fanout_max = 2;
        }
        let mut leader = Node::new(0, cfg.clone(), 1);
        let mut f1 = Node::new(1, cfg.clone(), 2);
        let mut f2 = Node::new(2, cfg.clone(), 3);
        let boot = leader.bootstrap_leader(0);
        f1.bootstrap_follower(0, 0);
        f2.bootstrap_follower(0, 0);

        // Deliver a batch of leader sends: everything for f1 flows (its
        // replies and relays back to the leader too); f2's copies are lost.
        fn deliver_except_f2(
            leader: &mut Node,
            f1: &mut Node,
            t: &mut u64,
            msgs: Vec<(usize, Message)>,
        ) {
            for (to, msg) in msgs {
                if to == 1 {
                    *t += 1;
                    let acts = f1.on_message(*t, msg);
                    for (to2, m2) in sends_of(&acts) {
                        if to2 == 0 {
                            *t += 1;
                            leader.on_message(*t, m2);
                        }
                    }
                }
                // to == 2: dropped (f2 misses the round entirely)
            }
        }
        let mut t: u64 = 10;
        deliver_except_f2(&mut leader, &mut f1, &mut t, sends_of(&boot));

        // Six rounds of traffic f2 never sees; the commit index races ahead
        // of f2's (empty) log, past the gossip batch-base margin.
        let mut last_round_msgs = Vec::new();
        for k in 0..6u64 {
            t += 1;
            leader.client_request(t, 100 + k, Command::Put { key: k, value: k });
            let dl = leader.next_deadline();
            t = t.max(dl) + 1;
            let acts = leader.tick(t);
            last_round_msgs = sends_of(&acts);
            deliver_except_f2(&mut leader, &mut f1, &mut t, last_round_msgs.clone());
        }
        assert!(
            leader.commit_index() >= 2,
            "{variant:?}: leader+f1 majority must commit without f2 (commit={})",
            leader.commit_index()
        );
        assert_eq!(f2.last_index(), 0, "{variant:?}: f2 missed everything");

        // f2 finally receives a round: the batch base has moved past its
        // log end, so it must NACK (both variants respond on failure).
        let (_, round_msg) = last_round_msgs
            .iter()
            .find(|(to, m)| *to == 2 && m.is_gossip())
            .cloned()
            .expect("fanout 2 targets f2 every round");
        t += 1;
        let nack_acts = f2.on_message(t, round_msg);
        let nacks: Vec<_> = sends_of(&nack_acts)
            .into_iter()
            .filter(|(to, m)| *to == 0 && matches!(m, Message::AppendEntriesReply(_)))
            .collect();
        assert_eq!(nacks.len(), 1, "{variant:?}: mismatch must trigger a repair NACK");
        if let Message::AppendEntriesReply(r) = &nacks[0].1 {
            assert!(!r.success, "{variant:?}: the round must log-mismatch at f2");
        }

        // Leader answers with classic (non-gossip) catch-up RPCs; walk the
        // repair conversation until it converges.
        t += 1;
        let mut repair_msgs = sends_of(&leader.on_message(t, nacks[0].1.clone()));
        let mut classic_rpcs = 0;
        let mut guard = 0;
        while !repair_msgs.is_empty() && guard < 16 {
            guard += 1;
            let mut next = Vec::new();
            for (to, msg) in repair_msgs.drain(..) {
                if to != 2 {
                    continue;
                }
                if let Message::AppendEntries(args) = &msg {
                    assert!(
                        args.gossip.is_none(),
                        "{variant:?}: repair must use classic RPCs"
                    );
                    classic_rpcs += 1;
                }
                t += 1;
                for (to2, m2) in sends_of(&f2.on_message(t, msg)) {
                    if to2 == 0 {
                        t += 1;
                        next.extend(sends_of(&leader.on_message(t, m2)));
                    }
                }
            }
            repair_msgs = next;
        }
        assert!(classic_rpcs >= 1, "{variant:?}: at least one classic repair RPC");
        assert_eq!(
            f2.last_index(),
            leader.last_index(),
            "{variant:?}: f2 must catch up to the leader's log"
        );
        for idx in 1..=leader.commit_index() {
            assert_eq!(
                f2.log().get(idx),
                leader.log().get(idx),
                "{variant:?}: repaired log must match at {idx}"
            );
        }
        assert!(leader.counters.repair_rpcs >= 1, "{variant:?}: repair path exercised");
    }
}

// ---------------------------------------------------------------------------
// Anti-entropy pull: request/reply mechanics, duplicate and stale replies,
// and progress under the PR 1 Gilbert–Elliott burst-loss knobs.
// ---------------------------------------------------------------------------

#[test]
fn pull_follower_fetches_batches_and_acks_durable_progress() {
    let cfg = ProtocolConfig::for_variant(3, Variant::Pull);
    let mut leader = Node::new(0, cfg.clone(), 1);
    let mut f2 = Node::new(2, cfg.clone(), 3);
    leader.bootstrap_leader(0);
    f2.bootstrap_follower(0, 0);
    for k in 0..3u64 {
        leader.client_request(1 + k, k, Command::Put { key: k, value: k });
    }

    // The follower's first pull fires from its strategy-side timer.
    let dl = f2.next_deadline();
    assert!(dl < f2.config().election_timeout_min_us, "pull timer precedes elections");
    let acts = f2.tick(dl);
    let reqs: Vec<_> = sends_of(&acts)
        .into_iter()
        .filter(|(_, m)| matches!(m, Message::PullRequest(_)))
        .collect();
    assert_eq!(reqs.len(), 2, "pull_fanout=2 asks both peers");
    let (_, req_msg) = reqs
        .iter()
        .find(|(to, _)| *to == 0)
        .cloned()
        .expect("n=3: both peers asked, leader among them");

    // The leader serves a matched continuation of the empty log.
    let racts = leader.on_message(5, req_msg);
    let replies: Vec<_> = sends_of(&racts)
        .into_iter()
        .filter(|(to, m)| *to == 2 && matches!(m, Message::PullReply(_)))
        .collect();
    assert_eq!(replies.len(), 1);
    if let Message::PullReply(r) = &replies[0].1 {
        assert!(r.matched);
        assert_eq!(r.entries.len(), 4, "noop + three puts");
        assert_eq!(r.prev_log_index, 0);
    }

    // The follower reconciles the batch, then acks the leader once with
    // the highest current-term index.
    let reply = replies[0].1.clone();
    let out1 = f2.on_message(6, reply.clone());
    assert_eq!(f2.last_index(), 4);
    let acks: Vec<_> = sends_of(&out1)
        .into_iter()
        .filter(|(to, m)| {
            *to == 0 && matches!(m, Message::AppendEntriesReply(r) if r.success && r.match_hint == 4)
        })
        .collect();
    assert_eq!(acks.len(), 1, "durable progress must be acked to the leader");

    // The leader folds the ack into its majority-match commit rule.
    let commit_acts = leader.on_message(7, acks[0].1.clone());
    assert_eq!(leader.commit_index(), 4, "leader + f2 = majority of 3");
    assert!(commit_acts
        .iter()
        .any(|a| matches!(a, epiraft::raft::Action::Committed { .. })));
}

#[test]
fn pull_reply_duplicates_and_stale_terms_are_inert() {
    let cfg = ProtocolConfig::for_variant(3, Variant::Pull);
    let mut leader = Node::new(0, cfg.clone(), 1);
    let mut f2 = Node::new(2, cfg.clone(), 3);
    leader.bootstrap_leader(0);
    f2.bootstrap_follower(0, 0);
    for k in 0..3u64 {
        leader.client_request(1 + k, k, Command::Put { key: k, value: k });
    }
    let dl = f2.next_deadline();
    let acts = f2.tick(dl);
    let (_, req_msg) = sends_of(&acts)
        .into_iter()
        .find(|(to, m)| *to == 0 && matches!(m, Message::PullRequest(_)))
        .expect("pull to the leader");
    let (_, reply) = sends_of(&leader.on_message(5, req_msg))
        .into_iter()
        .find(|(_, m)| matches!(m, Message::PullReply(_)))
        .expect("served reply");

    // First delivery applies and acks.
    let out1 = f2.on_message(6, reply.clone());
    assert_eq!(f2.last_index(), 4);
    assert_eq!(sends_of(&out1).len(), 1, "exactly one ack");

    // Duplicate delivery (the network may duplicate): idempotent reconcile,
    // no double ack, no commit movement.
    let commit_before = f2.commit_index();
    let out2 = f2.on_message(7, reply.clone());
    assert_eq!(f2.last_index(), 4, "no re-append");
    assert!(sends_of(&out2).is_empty(), "duplicate reply must not re-ack");
    assert_eq!(f2.commit_index(), commit_before);
    assert!(f2.counters.pull_stale >= 1, "duplicate counted as stale");

    // A reply from a superseded term is dropped outright. Push f2 to term
    // 2 via a higher-term vote request (the universal step-up rule).
    f2.on_message(
        8,
        Message::RequestVote(epiraft::raft::RequestVoteArgs {
            term: 2,
            candidate: 1,
            last_log_index: 99,
            last_log_term: 9,
            gossip: false,
            hops: 0,
        }),
    );
    assert_eq!(f2.term(), 2);
    let out3 = f2.on_message(9, reply);
    assert!(sends_of(&out3).is_empty(), "stale-term reply dropped");
    assert_eq!(f2.last_index(), 4);
}

#[test]
fn pull_reply_from_stale_laggard_never_truncates_newer_tail() {
    // A laggard whose log matches the requester's *anchor* but whose tail
    // is from an older term must not roll back newer entries: they may
    // already be acked into the leader's monotone match_index, so a
    // truncation here could let the leader commit an index a counted
    // majority member no longer holds. Truncation is exclusively the
    // leader's AppendEntries repair path.
    use epiraft::raft::{AppendEntriesArgs, LogEntry, PullReplyArgs};
    use std::sync::Arc;
    let e = |term: u64, index: u64| LogEntry {
        term,
        index,
        cmd: Command::Put { key: index, value: index },
    };
    let cfg = ProtocolConfig::for_variant(3, Variant::Pull);
    let mut f2 = Node::new(2, cfg, 3);
    f2.bootstrap_follower(0, 0);
    // Term-1 prefix from the old leader, then a term-2 leader overwrites
    // nothing but extends the log with current-term entries.
    f2.on_message(
        1,
        Message::AppendEntries(AppendEntriesArgs {
            term: 1,
            leader: 0,
            prev_log_index: 0,
            prev_log_term: 0,
            entries: Arc::new(vec![e(1, 1), e(1, 2)]),
            leader_commit: 1,
            gossip: None,
            seq: 1,
        }),
    );
    f2.on_message(
        2,
        Message::AppendEntries(AppendEntriesArgs {
            term: 2,
            leader: 1,
            prev_log_index: 2,
            prev_log_term: 1,
            entries: Arc::new(vec![e(2, 3), e(2, 4)]),
            leader_commit: 1,
            gossip: None,
            seq: 1,
        }),
    );
    assert_eq!(f2.term(), 2);
    assert_eq!(f2.last_index(), 4);
    assert_eq!(f2.commit_index(), 1);

    // A laggard at the same term number (it voted, but never saw the
    // term-2 entries) serves a "matched" continuation of the (2, term 1)
    // anchor — its own stale term-1 tail.
    f2.on_message(
        3,
        Message::PullReply(PullReplyArgs {
            term: 2,
            from: 0,
            prev_log_index: 2,
            prev_log_term: 1,
            matched: true,
            diverged: false,
            entries: Arc::new(vec![e(1, 3), e(1, 4), e(1, 5)]),
            commit_index: 2,
            leader_hint: Some(1),
            known_round: 0,
        }),
    );
    // The newer tail survives untouched and the reply is counted stale...
    assert_eq!(f2.last_index(), 4);
    assert_eq!(f2.log().get(3).unwrap().term, 2);
    assert_eq!(f2.log().get(4).unwrap().term, 2);
    assert!(f2.counters.pull_stale >= 1, "conflicting suffix counted stale");
    // ...while the responder's commit index is still adopted over the
    // anchor-verified shared prefix.
    assert_eq!(f2.commit_index(), 2);
}

#[test]
fn diverged_report_from_laggard_cannot_demote_current_term_anchor() {
    // Responders report `diverged` whenever they hold a different term at
    // the anchor — including when *they* are the stale party. A requester
    // whose tail is pinned to the current term knows its whole log matches
    // the leader's, so it must keep pulling from its tail; only a
    // non-current-term tail may be re-anchored at the commit index.
    use epiraft::raft::{AppendEntriesArgs, LogEntry, PullReplyArgs, PullRequestArgs};
    use std::sync::Arc;
    let e = |term: u64, index: u64| LogEntry {
        term,
        index,
        cmd: Command::Put { key: index, value: index },
    };
    let cfg = ProtocolConfig::for_variant(3, Variant::Pull);
    let mut f2 = Node::new(2, cfg, 3);
    f2.bootstrap_follower(0, 0);
    f2.on_message(
        1,
        Message::AppendEntries(AppendEntriesArgs {
            term: 1,
            leader: 0,
            prev_log_index: 0,
            prev_log_term: 0,
            entries: Arc::new(vec![e(1, 1), e(1, 2)]),
            leader_commit: 1,
            gossip: None,
            seq: 1,
        }),
    );
    assert_eq!((f2.last_index(), f2.commit_index()), (2, 1));
    let diverged_reply = |term: u64| {
        Message::PullReply(PullReplyArgs {
            term,
            from: 1,
            prev_log_index: 2,
            prev_log_term: 1,
            matched: false,
            diverged: true,
            entries: Arc::new(Vec::new()),
            commit_index: 0,
            leader_hint: Some(0),
            known_round: 0,
        })
    };
    let pull_anchors = |node: &mut Node, t: u64| -> Vec<(u64, u64)> {
        let dl = node.next_deadline().max(t);
        sends_of(&node.tick(dl))
            .into_iter()
            .filter_map(|(_, m)| match m {
                Message::PullRequest(PullRequestArgs { from_index, from_term, .. }) => {
                    Some((from_index, from_term))
                }
                _ => None,
            })
            .collect()
    };

    // Tail pinned to the current term (1): the report is ignored, the next
    // pull still anchors at the tail.
    f2.on_message(3, diverged_reply(1));
    let anchors = pull_anchors(&mut f2, 4);
    assert!(!anchors.is_empty(), "follower keeps pulling");
    assert!(anchors.iter().all(|&a| a == (2, 1)), "healthy tail anchor kept: {anchors:?}");

    // Step the term up (vote request from a fresher candidate): the tail
    // is no longer current-term, so the same report is now honored and the
    // next pull re-anchors at the commit index.
    f2.on_message(
        5,
        Message::RequestVote(epiraft::raft::RequestVoteArgs {
            term: 2,
            candidate: 1,
            last_log_index: 99,
            last_log_term: 9,
            gossip: false,
            hops: 0,
        }),
    );
    assert_eq!(f2.term(), 2);
    f2.on_message(6, diverged_reply(2));
    let anchors = pull_anchors(&mut f2, 7);
    assert!(!anchors.is_empty(), "follower keeps pulling");
    assert!(anchors.iter().all(|&a| a == (1, 1)), "re-anchored at commit: {anchors:?}");
}

#[test]
fn stale_term_pull_request_teaches_the_requester_the_term() {
    let cfg = ProtocolConfig::for_variant(3, Variant::Pull);
    let mut responder = Node::new(1, cfg.clone(), 2);
    responder.bootstrap_follower(0, 0);
    // Push the responder to term 4 via a higher-term vote request.
    responder.on_message(
        1,
        Message::RequestVote(epiraft::raft::RequestVoteArgs {
            term: 4,
            candidate: 0,
            last_log_index: 99,
            last_log_term: 9,
            gossip: false,
            hops: 0,
        }),
    );
    assert_eq!(responder.term(), 4);
    let req = epiraft::raft::PullRequestArgs {
        term: 1,
        from: 2,
        from_index: 0,
        from_term: 0,
        known_round: 0,
    };
    let out = responder.on_message(2, Message::PullRequest(req));
    let (to, msg) = &sends_of(&out)[0];
    assert_eq!(*to, 2);
    match msg {
        Message::PullReply(r) => {
            assert_eq!(r.term, 4, "reply carries the newer term");
            assert!(!r.matched && r.entries.is_empty(), "no entries across terms");
        }
        other => panic!("unexpected {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// Adaptive fanout (PR 3): the AIMD controller's visible trajectory at the
// leader — NACKs widen the seed fanout, clean acks decay it to fanout_min.
// ---------------------------------------------------------------------------

#[test]
fn adaptive_seed_fanout_widens_on_nacks_and_decays_on_acks() {
    use epiraft::raft::AppendEntriesReply;
    let mut cfg = ProtocolConfig::for_variant(9, Variant::Pull);
    cfg.fanout = 3;
    cfg.adaptive.enabled = true; // defaults: min 1, max 8, gain 1, backoff 0.8
    let mut leader = Node::new(0, cfg, 1);
    leader.bootstrap_leader(0);
    assert_eq!(
        leader.counters.fanout_current, 3,
        "first round plans at the static base fanout"
    );
    let reply = |from: usize, success: bool, match_hint: u64| {
        Message::AppendEntriesReply(AppendEntriesReply {
            term: 1,
            from,
            success,
            match_hint,
            round: Some(1),
            epidemic: None,
            seq: 0,
        })
    };
    // A follower NACKs (behind the batch base): the next round widens.
    let mut t = 1;
    leader.on_message(t, reply(1, false, 0));
    t = leader.next_deadline().max(t + 1);
    leader.tick(t);
    assert_eq!(leader.counters.fanout_current, 4, "additive increase after a NACK round");
    assert!(leader.counters.fanout_adaptations >= 1);
    // Rounds of clean acks decay the fanout back down to fanout_min.
    for round in 0..12u64 {
        let from = 1 + (round as usize % 4);
        let hint = leader.last_index();
        leader.on_message(t + 1, reply(from, true, hint));
        t = leader.next_deadline().max(t + 2);
        leader.tick(t);
    }
    assert_eq!(
        leader.counters.fanout_current, 1,
        "clean steady state must settle at fanout_min"
    );
    assert!(leader.counters.fanout_max_seen <= 8 && leader.counters.fanout_min_seen >= 1);
}

#[test]
fn pull_progress_and_safety_under_burst_loss() {
    // PR 1's Gilbert–Elliott knobs: ~2% of packets enter a bad state that
    // drops 90% and lasts ~20 packets, plus 5% duplication to exercise the
    // duplicate-reply path at sim scale. Elections are allowed (bursts can
    // legitimately depose a leader); safety and progress are not optional.
    let mut cfg = Config::default();
    cfg.protocol.n = 9;
    cfg.protocol.variant = Variant::Pull;
    cfg.workload.clients = 8;
    cfg.workload.duration_us = 4_000_000;
    cfg.workload.warmup_us = 500_000;
    cfg.network.ge_good_to_bad = 0.02;
    cfg.network.ge_bad_to_good = 0.05;
    cfg.network.ge_loss_good = 0.0;
    cfg.network.ge_loss_bad = 0.9;
    cfg.network.duplicate = 0.05;
    cfg.seed = 0xB1457;
    let report = run_experiment(&cfg);
    assert!(report.safety_ok, "committed prefixes diverged under burst loss");
    assert!(report.completed > 0, "no requests served under burst loss");
    assert!(report.max_commit > 0, "nothing committed under burst loss");
}

// ---------------------------------------------------------------------------
// Unreliable-node mode (PR 4, `raft::view`): k flaky replicas are demoted
// out of the quorum and the cluster still commits the client load.
// ---------------------------------------------------------------------------

#[test]
fn flaky_replicas_are_demoted_and_the_cluster_still_commits() {
    use epiraft::config::LinkSpec;
    for variant in [Variant::Raft, Variant::Pull] {
        let mut cfg = Config::default();
        cfg.protocol.n = 9;
        cfg.protocol.variant = variant;
        cfg.protocol.unreliable.enabled = true;
        // Election timeouts above the slow replicas' round-trip delay:
        // their heartbeat stream arrives late but regularly, so they must
        // read as slow, not dead (see harness::unreliable).
        cfg.protocol.election_timeout_min_us = 1_000_000;
        cfg.protocol.election_timeout_max_us = 2_000_000;
        cfg.workload.clients = 8;
        cfg.workload.rate = 400.0;
        cfg.workload.duration_us = 3_000_000;
        cfg.workload.warmup_us = 400_000;
        cfg.seed = 0x0DD_BA11;
        // k = 2 permanently-slow replicas (asymmetric per-link delay in
        // both directions — reachable, in-order, far too late).
        for id in [7usize, 8] {
            cfg.network.links.push(LinkSpec { selector: id.to_string(), extra_us: 250_000 });
        }
        let report = run_experiment(&cfg);
        assert!(report.safety_ok, "{variant:?}: demotion churn broke safety");
        assert!(report.completed > 100, "{variant:?}: flaky peers stalled the cluster");
        assert_eq!(report.elections, 0, "{variant:?}: flaky peers deposed the leader");
        assert!(
            report.demotions >= 2,
            "{variant:?}: both flaky replicas must be demoted (saw {})",
            report.demotions
        );
        assert_eq!(
            report.demoted_current, 2,
            "{variant:?}: still-slow replicas must stay demoted at end of run"
        );
        assert!(
            report.best_effort_bytes > 0,
            "{variant:?}: demoted replicas must still be reached best-effort"
        );
    }
}
