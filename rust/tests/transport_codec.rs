//! Codec round-trip property tests + the size-model honesty test.
//!
//! Seeded `Xoshiro256` generators (no proptest dependency, same as
//! `prop_safety.rs`) build randomized instances of every [`Message`]
//! variant; each must (a) survive encode→decode bit-exactly and (b)
//! occupy exactly `Message::wire_bytes()` bytes on the wire — the
//! equality that keeps the simulator's egress numbers meaningful
//! (BENCH_PR2–PR4 all gate on them).

use epiraft::epidemic::{EpidemicPayload, EpidemicState};
use epiraft::kvstore::Command;
use epiraft::raft::{
    AppendEntriesArgs, AppendEntriesReply, GossipMeta, LogEntry, Message, PullReplyArgs,
    PullRequestArgs, RequestVoteArgs, RequestVoteReply,
};
use epiraft::transport::codec::{self, DecodeError};
use epiraft::util::rng::Xoshiro256;
use std::sync::Arc;

fn arb_command(rng: &mut Xoshiro256) -> Command {
    match rng.next_below(4) {
        0 => Command::Noop,
        1 => Command::Put { key: rng.next_u64(), value: rng.next_u64() },
        2 => Command::Get { key: rng.next_u64() },
        _ => Command::Delete { key: rng.next_u64() },
    }
}

fn arb_entries(rng: &mut Xoshiro256, max: u64) -> Arc<Vec<LogEntry>> {
    let count = rng.next_below(max + 1);
    Arc::new(
        (0..count)
            .map(|i| LogEntry {
                term: rng.next_below(1 << 40),
                index: rng.next_below(1 << 40) + i,
                cmd: arb_command(rng),
            })
            .collect(),
    )
}

fn arb_epidemic(rng: &mut Xoshiro256) -> Option<EpidemicPayload> {
    if rng.next_below(2) == 0 {
        return None;
    }
    // Up to several bitmap words, so multi-word layouts are exercised.
    let n = 1 + rng.next_below(130) as usize;
    let mut s = EpidemicState::new(n);
    // Mixed densities: ~1/3 set bits forces the dense repr even under
    // `compact`, ~1/48 usually crosses into sparse — both wire encodings
    // and the crossover itself are exercised.
    let denom = if rng.next_below(2) == 0 { 3 } else { 48 };
    for i in 0..n {
        if rng.next_below(denom) == 0 {
            s.bitmap.set(i);
        }
    }
    s.max_commit = rng.next_below(1 << 30);
    s.next_commit = s.max_commit + 1 + rng.next_below(64);
    let compact = rng.next_below(2) == 0;
    Some(EpidemicPayload::from_state(&s, compact))
}

fn arb_gossip(rng: &mut Xoshiro256) -> Option<GossipMeta> {
    if rng.next_below(2) == 0 {
        return None;
    }
    Some(GossipMeta {
        round: rng.next_u64(),
        hops: rng.next_below(1 << 16) as u32,
        epidemic: arb_epidemic(rng),
    })
}

/// One randomized message; `shape % 6` picks the variant so a sweep over
/// consecutive shapes covers all six.
fn arb_message(rng: &mut Xoshiro256, shape: u64) -> Message {
    let node = |rng: &mut Xoshiro256| rng.next_below(1 << 20) as usize;
    match shape % 6 {
        0 => Message::AppendEntries(AppendEntriesArgs {
            term: rng.next_below(1 << 40),
            leader: node(rng),
            prev_log_index: rng.next_below(1 << 40),
            prev_log_term: rng.next_below(1 << 40),
            entries: arb_entries(rng, 40),
            leader_commit: rng.next_below(1 << 40),
            gossip: arb_gossip(rng),
            seq: rng.next_u64(),
        }),
        1 => Message::AppendEntriesReply(AppendEntriesReply {
            term: rng.next_below(1 << 40),
            from: node(rng),
            success: rng.next_below(2) == 0,
            match_hint: rng.next_below(1 << 40),
            round: (rng.next_below(2) == 0).then(|| rng.next_u64()),
            epidemic: arb_epidemic(rng),
            seq: rng.next_u64(),
        }),
        2 => Message::RequestVote(RequestVoteArgs {
            term: rng.next_below(1 << 40),
            candidate: node(rng),
            last_log_index: rng.next_below(1 << 40),
            last_log_term: rng.next_below(1 << 40),
            gossip: rng.next_below(2) == 0,
            hops: rng.next_below(1 << 16) as u32,
        }),
        3 => Message::RequestVoteReply(RequestVoteReply {
            term: rng.next_below(1 << 40),
            from: node(rng),
            granted: rng.next_below(2) == 0,
        }),
        4 => Message::PullRequest(PullRequestArgs {
            term: rng.next_below(1 << 40),
            from: node(rng),
            from_index: rng.next_below(1 << 40),
            from_term: rng.next_below(1 << 40),
            known_round: rng.next_u64(),
        }),
        _ => Message::PullReply(PullReplyArgs {
            term: rng.next_below(1 << 40),
            from: node(rng),
            prev_log_index: rng.next_below(1 << 40),
            prev_log_term: rng.next_below(1 << 40),
            matched: rng.next_below(2) == 0,
            diverged: rng.next_below(2) == 0,
            entries: arb_entries(rng, 40),
            commit_index: rng.next_below(1 << 40),
            leader_hint: (rng.next_below(2) == 0).then(|| node(rng)),
            known_round: rng.next_u64(),
        }),
    }
}

#[test]
fn roundtrip_every_variant_randomized() {
    let mut rng = Xoshiro256::seed_from_u64(0xC0DEC);
    for shape in 0..600 {
        let msg = arb_message(&mut rng, shape);
        let buf = codec::encode_to_vec(&msg);
        let (decoded, consumed) =
            codec::decode(&buf).expect("decode").unwrap_or_else(|| panic!("incomplete {shape}"));
        assert_eq!(consumed, buf.len(), "whole frame consumed (shape {shape})");
        assert_eq!(decoded, msg, "encode/decode must round-trip (shape {shape})");
    }
}

#[test]
fn wire_bytes_equals_encoded_frame_length() {
    // The honesty test: the egress size model IS the frame length — no
    // slack constant, for every variant and payload shape. If a codec or
    // model change breaks this, fix whichever side diverged; do not widen
    // the assertion.
    let mut rng = Xoshiro256::seed_from_u64(0x512E_4D0D);
    for shape in 0..600 {
        let msg = arb_message(&mut rng, shape);
        let buf = codec::encode_to_vec(&msg);
        assert_eq!(
            buf.len() as u64,
            msg.wire_bytes(),
            "wire_bytes must equal the encoded frame length ({}, shape {shape})",
            msg.kind()
        );
    }
}

#[test]
fn frame_streams_decode_message_by_message() {
    let mut rng = Xoshiro256::seed_from_u64(7);
    let msgs: Vec<Message> = (0..24).map(|s| arb_message(&mut rng, s)).collect();
    let mut stream = Vec::new();
    for m in &msgs {
        codec::encode(m, &mut stream);
    }
    let mut at = 0;
    let mut decoded = Vec::new();
    while at < stream.len() {
        let (m, used) = codec::decode(&stream[at..]).expect("decode").expect("complete");
        decoded.push(m);
        at += used;
    }
    assert_eq!(decoded, msgs);
    // The same stream through the incremental reader API.
    let mut r = std::io::Cursor::new(stream);
    for m in &msgs {
        assert_eq!(codec::read_frame(&mut r).expect("read").as_ref(), Some(m));
    }
    assert_eq!(codec::read_frame(&mut r).expect("read"), None, "clean EOF");
}

#[test]
fn truncated_frames_are_rejected_not_misread() {
    let mut rng = Xoshiro256::seed_from_u64(99);
    for shape in 0..12 {
        let msg = arb_message(&mut rng, shape);
        let buf = codec::encode_to_vec(&msg);
        // Frame-level: any prefix is "need more bytes", never a message.
        for cut in 0..buf.len() {
            assert_eq!(
                codec::decode(&buf[..cut]).expect("prefix must not error"),
                None,
                "prefix of length {cut} must not decode (shape {shape})"
            );
        }
        // Payload-level: a frame whose body was cut short is Truncated.
        let payload = &buf[4..];
        for cut in 2..payload.len() {
            assert_eq!(
                codec::decode_payload(&payload[..cut]).unwrap_err(),
                DecodeError::Truncated,
                "payload cut at {cut} (shape {shape})"
            );
        }
    }
}

#[test]
fn bad_version_bytes_are_rejected() {
    let mut rng = Xoshiro256::seed_from_u64(3);
    let buf = codec::encode_to_vec(&arb_message(&mut rng, 0));
    for v in [0u8, 2, 7, 255] {
        let mut bad = buf.clone();
        bad[4] = v;
        assert_eq!(codec::decode(&bad).unwrap_err(), DecodeError::BadVersion(v));
    }
}

#[test]
fn oversized_and_undersized_length_prefixes_are_rejected() {
    let mut rng = Xoshiro256::seed_from_u64(4);
    let buf = codec::encode_to_vec(&arb_message(&mut rng, 1));
    for len in [0u32, 1, codec::MAX_FRAME_LEN + 1, u32::MAX] {
        let mut bad = buf.clone();
        bad[..4].copy_from_slice(&len.to_le_bytes());
        assert_eq!(
            codec::decode(&bad).unwrap_err(),
            DecodeError::BadLength(len),
            "length prefix {len}"
        );
    }
}

/// Byte offset of the epidemic repr tag inside an encoded
/// `AppendEntriesReply` frame with `round: Some(_)`: frame len(4) +
/// version(1) + kind(1) + term(8) + from(4) + success(1) + match_hint(8)
/// + round presence(1) + round(8) + seq(8).
const REPLY_EPI_REPR_AT: usize = 4 + 2 + 8 + 4 + 1 + 8 + 1 + 8 + 8;

/// A deterministic `AppendEntriesReply` carrying a forced-sparse epidemic
/// payload (3 set bits out of n=51), for byte-surgery tests below.
fn sparse_reply_frame() -> Vec<u8> {
    let payload = EpidemicPayload::sparse_from_indices(51, 10, 11, vec![3, 10, 40])
        .expect("valid sparse payload");
    let msg = Message::AppendEntriesReply(AppendEntriesReply {
        term: 5,
        from: 2,
        success: true,
        match_hint: 10,
        round: Some(7),
        epidemic: Some(payload),
        seq: 1,
    });
    let buf = codec::encode_to_vec(&msg);
    assert_eq!(buf[REPLY_EPI_REPR_AT], 2, "sparse repr tag where expected");
    codec::decode(&buf).expect("pristine frame decodes").expect("complete");
    buf
}

#[test]
fn sparse_structural_corruption_is_rejected_without_panic() {
    // EPI_SPARSE is a length-prefixed list of set-bit indices that must be
    // strictly increasing and < n. A peer sending anything else must cost
    // us one Malformed error — never a panic, a bogus bitmap, or an OOM.
    let buf = sparse_reply_frame();
    // Index stream starts after repr(1) + n(4) + max(8) + next(8) + count(4).
    let ix0 = REPLY_EPI_REPR_AT + 1 + 4 + 8 + 8 + 4;
    let idx = |buf: &[u8], k: usize| {
        u32::from_le_bytes(buf[ix0 + 4 * k..ix0 + 4 * k + 4].try_into().unwrap())
    };
    assert_eq!([idx(&buf, 0), idx(&buf, 1), idx(&buf, 2)], [3, 10, 40]);

    // Out of range: an index >= n (both barely and absurdly).
    for bad_index in [51u32, u32::MAX] {
        let mut bad = buf.clone();
        bad[ix0 + 8..ix0 + 12].copy_from_slice(&bad_index.to_le_bytes());
        assert!(
            matches!(codec::decode(&bad).unwrap_err(), DecodeError::Malformed(_)),
            "index {bad_index} >= n must be Malformed"
        );
    }

    // Duplicate: repeat the first index into the second slot.
    let mut dup = buf.clone();
    let first: [u8; 4] = dup[ix0..ix0 + 4].try_into().unwrap();
    dup[ix0 + 4..ix0 + 8].copy_from_slice(&first);
    assert!(matches!(codec::decode(&dup).unwrap_err(), DecodeError::Malformed(_)));

    // Unsorted: swap the first and last indices (40, 10, 3).
    let mut unsorted = buf.clone();
    let (a, c) = (idx(&buf, 0), idx(&buf, 2));
    unsorted[ix0..ix0 + 4].copy_from_slice(&c.to_le_bytes());
    unsorted[ix0 + 8..ix0 + 12].copy_from_slice(&a.to_le_bytes());
    assert!(matches!(codec::decode(&unsorted).unwrap_err(), DecodeError::Malformed(_)));
}

#[test]
fn sparse_count_bomb_is_rejected_before_allocating() {
    // A hostile count prefix far beyond the actual bytes must fail the
    // remaining-bytes check (Truncated), not drive a with_capacity OOM.
    let buf = sparse_reply_frame();
    let count_at = REPLY_EPI_REPR_AT + 1 + 4 + 8 + 8;
    for bomb in [u32::MAX, 1 << 30, 4] {
        let mut bad = buf.clone();
        bad[count_at..count_at + 4].copy_from_slice(&bomb.to_le_bytes());
        assert_eq!(
            codec::decode(&bad).unwrap_err(),
            DecodeError::Truncated,
            "count {bomb} must be rejected as truncated"
        );
    }
}

#[test]
fn unknown_kinds_and_booleans_are_rejected() {
    let mut rng = Xoshiro256::seed_from_u64(5);
    let buf = codec::encode_to_vec(&arb_message(&mut rng, 3)); // vote reply
    let mut bad = buf.clone();
    bad[5] = 42; // kind byte
    assert_eq!(codec::decode(&bad).unwrap_err(), DecodeError::BadKind(42));
    // The final body byte of a vote reply is its `granted` boolean.
    let mut bad = buf;
    let at = bad.len() - 1;
    bad[at] = 7;
    assert!(matches!(codec::decode(&bad).unwrap_err(), DecodeError::Malformed(_)));
}
