//! Property-based safety tests (DESIGN.md §8): for all three protocol
//! variants, under random fault schedules (crashes, partitions, loss
//! bursts), the cluster never violates Raft's state-machine safety — no
//! two replicas disagree on any committed prefix — and the epidemic
//! structure algebra preserves its invariants under arbitrary
//! interleavings.

use epiraft::config::Config;
use epiraft::epidemic::{EpidemicState, LogView};
use epiraft::prop::{forall, Gen};
use epiraft::raft::Variant;
use epiraft::sim::{run_with_faults, FaultSchedule, Simulation};
use epiraft::util::rng::Xoshiro256;

fn random_cfg(g: &mut Gen, variant: Variant) -> Config {
    let mut cfg = Config::default();
    cfg.protocol.n = *g.choice(&[3usize, 5, 7, 9]);
    cfg.protocol.variant = variant;
    cfg.protocol.fanout = g.usize_in(1, 5);
    cfg.protocol.round_interval_us = g.u64_in(1_000, 10_000);
    cfg.workload.clients = g.usize_in(1, 8);
    cfg.workload.duration_us = 3_000_000;
    cfg.workload.warmup_us = 300_000;
    cfg.network.loss = if g.bool_with(0.3) { g.f64_unit() * 0.1 } else { 0.0 };
    cfg.seed = g.u64_in(0, u64::MAX - 1);
    cfg
}

#[test]
fn safety_under_random_faults_raft() {
    safety_under_random_faults(Variant::Raft);
}

#[test]
fn safety_under_random_faults_v1() {
    safety_under_random_faults(Variant::V1);
}

#[test]
fn safety_under_random_faults_v2() {
    safety_under_random_faults(Variant::V2);
}

#[test]
fn safety_under_random_faults_pull() {
    safety_under_random_faults(Variant::Pull);
}

fn safety_under_random_faults(variant: Variant) {
    forall(&format!("safety-{}", variant.name()), 12, |g| {
        let cfg = random_cfg(g, variant);
        let mut rng = Xoshiro256::seed_from_u64(cfg.seed ^ 0xFA17);
        let faults = FaultSchedule::random(
            &mut rng,
            cfg.protocol.n,
            cfg.workload.duration_us,
            5,
        );
        let report = run_with_faults(&cfg, faults);
        assert!(
            report.safety_ok,
            "variant {variant:?} violated committed-prefix agreement (n={}, seed={})",
            cfg.protocol.n, cfg.seed
        );
    });
}

#[test]
fn adaptive_fanout_safe_and_bounded_under_random_faults() {
    // PR 3: with the AIMD controller enabled and a randomized clamp
    // window, random fault schedules must neither break safety nor drive
    // any replica's effective fanout outside [fanout_min, fanout_max]
    // (the gossip variants may clamp *up* to their liveness floor of 2,
    // which stays inside the window by construction here).
    forall("safety-adaptive", 12, |g| {
        let variant = *g.choice(&[Variant::V1, Variant::V2, Variant::Pull]);
        let mut cfg = random_cfg(g, variant);
        cfg.protocol.adaptive.enabled = true;
        cfg.protocol.adaptive.fanout_min = g.usize_in(1, 3);
        cfg.protocol.adaptive.fanout_max = g.usize_in(4, 9);
        cfg.protocol.adaptive.gain = 0.5 + g.f64_unit() * 2.0;
        cfg.protocol.adaptive.backoff = 0.5 + g.f64_unit() * 0.4;
        let mut rng = Xoshiro256::seed_from_u64(cfg.seed ^ 0xADA7);
        let faults = FaultSchedule::random(
            &mut rng,
            cfg.protocol.n,
            cfg.workload.duration_us,
            5,
        );
        let report = run_with_faults(&cfg, faults);
        assert!(
            report.safety_ok,
            "adaptive {variant:?} violated committed-prefix agreement (n={}, seed={})",
            cfg.protocol.n, cfg.seed
        );
        let hi = cfg.protocol.adaptive.fanout_max as u64;
        assert!(
            report.fanout_max_seen <= hi,
            "adaptive {variant:?}: fanout {} exceeded fanout_max {} (seed={})",
            report.fanout_max_seen,
            hi,
            cfg.seed
        );
        assert!(
            report.fanout_min_seen == 0
                || report.fanout_min_seen >= cfg.protocol.adaptive.fanout_min as u64,
            "adaptive {variant:?}: fanout {} fell below fanout_min {} (seed={})",
            report.fanout_min_seen,
            cfg.protocol.adaptive.fanout_min,
            cfg.seed
        );
    });
}

#[test]
fn unreliable_mode_safe_under_random_faults_and_flaky_links() {
    // PR 4: with unreliable-node mode enabled, random fault schedules plus
    // randomly-slowed replicas (asymmetric [sim.links] delays) must never
    // lose a committed entry across demote/re-promote churn — the
    // committed-prefix agreement holds at end of run for every variant,
    // whatever the demotion counters say.
    use epiraft::config::LinkSpec;
    forall("safety-unreliable", 12, |g| {
        let variant = *g.choice(&[Variant::Raft, Variant::Pull, Variant::V1]);
        let mut cfg = random_cfg(g, variant);
        cfg.protocol.unreliable.enabled = true;
        cfg.protocol.unreliable.demote_after = g.u64_in(1, 5) as u32;
        cfg.protocol.unreliable.probation = g.u64_in(1, 13) as u32;
        // A couple of randomly-chosen slow replicas (possibly the
        // bootstrap leader itself — demotion must survive leader churn).
        let slow = g.usize_in(0, 3);
        for _ in 0..slow {
            let id = g.usize_in(0, cfg.protocol.n);
            cfg.network.links.push(LinkSpec {
                selector: id.to_string(),
                extra_us: g.u64_in(50_000, 250_000),
            });
        }
        let mut rng = Xoshiro256::seed_from_u64(cfg.seed ^ 0xF1A2);
        let faults = FaultSchedule::random(
            &mut rng,
            cfg.protocol.n,
            cfg.workload.duration_us,
            5,
        );
        let report = run_with_faults(&cfg, faults);
        assert!(
            report.safety_ok,
            "unreliable {variant:?} lost a committed entry (n={}, seed={}, demotions={}, \
             promotions={})",
            cfg.protocol.n, cfg.seed, report.demotions, report.promotions
        );
    });
}

#[test]
fn liveness_without_faults_all_variants() {
    forall("liveness-no-faults", 9, |g| {
        for variant in Variant::ALL {
            let cfg = random_cfg(g, variant);
            let report = run_with_faults(&cfg, FaultSchedule::none());
            assert!(
                report.completed > 0,
                "variant {variant:?} made no progress (cfg seed {})",
                cfg.seed
            );
            assert!(report.safety_ok);
            if cfg.network.loss == 0.0 {
                // A lossy network may legitimately miss enough heartbeats
                // to trigger an election; a loss-free one must not.
                assert_eq!(report.elections, 0, "stable leader must not be deposed");
            }
        }
    });
}

// ---------------------------------------------------------------------------
// Epidemic structure algebra
// ---------------------------------------------------------------------------

fn random_state(g: &mut Gen, n: usize) -> EpidemicState {
    let mut s = EpidemicState::new(n);
    s.max_commit = g.u64_in(0, 500);
    s.next_commit = s.max_commit + g.u64_in(1, 50);
    let bits = g.usize_in(0, n + 1);
    for _ in 0..bits {
        let b = g.usize_in(0, n);
        s.bitmap.set(b);
    }
    s
}

fn random_log(g: &mut Gen) -> LogView {
    let term = g.u64_in(1, 5);
    LogView {
        last_index: g.u64_in(0, 600),
        last_term: if g.bool_with(0.7) { term } else { term - 1 },
        current_term: term,
    }
}

#[test]
fn merge_update_preserve_invariant() {
    forall("nextCommit > maxCommit invariant", 500, |g| {
        let n = *g.choice(&[3usize, 5, 51]);
        let majority = n / 2 + 1;
        let mut s = random_state(g, n);
        // Arbitrary interleaving of merges, updates and bit sets.
        for _ in 0..g.usize_in(1, 30) {
            match g.usize_in(0, 3) {
                0 => s.merge(&random_state(g, n)),
                1 => {
                    s.update(g.usize_in(0, n), majority, random_log(g));
                }
                _ => {
                    s.maybe_set_own_bit(g.usize_in(0, n), random_log(g));
                }
            }
            assert!(
                s.invariant_holds(),
                "invariant broken: mc={} nc={}",
                s.max_commit,
                s.next_commit
            );
        }
    });
}

#[test]
fn max_commit_is_monotone() {
    forall("maxCommit monotonicity", 300, |g| {
        let n = 5;
        let mut s = random_state(g, n);
        let mut last = s.max_commit;
        for _ in 0..g.usize_in(1, 20) {
            if g.bool_with(0.5) {
                s.merge(&random_state(g, n));
            } else {
                s.update(g.usize_in(0, n), 3, random_log(g));
            }
            assert!(s.max_commit >= last, "maxCommit regressed");
            last = s.max_commit;
        }
    });
}

#[test]
fn merge_is_idempotent_property() {
    forall("merge idempotence", 300, |g| {
        let n = 7;
        let mut s = random_state(g, n);
        let other = random_state(g, n);
        s.merge(&other);
        let once = s.clone();
        s.merge(&other);
        assert_eq!(s, once, "second merge of same state changed the result");
    });
}

#[test]
fn merge_commutes_on_max_commit() {
    // Full merge isn't commutative (bitmap adoption is order-sensitive by
    // design), but the *confirmed index* must converge regardless of
    // delivery order — that is what decentralised commit relies on.
    forall("maxCommit order-independence", 300, |g| {
        let n = 5;
        let a = random_state(g, n);
        let b = random_state(g, n);
        let base = random_state(g, n);
        let mut ab = base.clone();
        ab.merge(&a);
        ab.merge(&b);
        let mut ba = base.clone();
        ba.merge(&b);
        ba.merge(&a);
        assert_eq!(ab.max_commit, ba.max_commit);
    });
}

#[test]
fn permutation_covers_every_peer_each_cycle() {
    use epiraft::epidemic::Permutation;
    forall("permutation exact cover", 200, |g| {
        let n = g.usize_in(2, 64);
        let me = g.usize_in(0, n);
        let fanout = g.usize_in(1, 8);
        let mut rng = Xoshiro256::seed_from_u64(g.seed);
        let mut p = Permutation::new(n, me, &mut rng);
        let peers = n - 1;
        let rounds = peers.div_ceil(fanout);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..rounds {
            for t in p.next_round(fanout) {
                assert_ne!(t, me, "never gossip to self");
                seen.insert(t);
            }
        }
        assert_eq!(seen.len(), peers, "one cycle must contact every peer");
    });
}

#[test]
fn committed_entries_survive_leader_crash() {
    forall("durability across leader change", 8, |g| {
        for variant in Variant::ALL {
            let mut cfg = random_cfg(g, variant);
            cfg.protocol.n = 5;
            cfg.workload.duration_us = 5_000_000;
            // Crash the bootstrap leader mid-run; it stays down.
            let faults = FaultSchedule::leader_crash(1_500_000, 4_900_000, 0);
            let report = run_with_faults(&cfg, faults);
            assert!(report.safety_ok, "{variant:?}: divergence after leader crash");
            // The cluster kept (or re-established) service.
            assert!(
                report.max_commit > 0,
                "{variant:?}: nothing ever committed"
            );
        }
    });
}

#[test]
fn committed_prefix_monotone_across_random_kill_restart() {
    // PR 7: under random kill-and-restart schedules (process death losing
    // all volatile state, recovery from the Storage backend alone), every
    // variant must preserve the committed prefix each killed replica had
    // at the moment of death — the end-of-run cluster agrees on a log that
    // extends every recorded prefix (recovery_ok), on top of the usual
    // committed-prefix agreement (safety_ok). Half the cases also enable
    // snapshots + compaction so recovery exercises the snapshot path.
    for variant in Variant::ALL {
        forall(&format!("kill-restart-{}", variant.name()), 8, |g| {
            let mut cfg = random_cfg(g, variant);
            cfg.network.loss = 0.0; // isolate the kill/restart fault mode
            if g.bool_with(0.5) {
                cfg.protocol.storage.snapshot_interval_entries = g.u64_in(50, 300);
                cfg.protocol.storage.retain_entries =
                    cfg.protocol.storage.snapshot_interval_entries + g.u64_in(0, 200);
            }
            let mut rng = Xoshiro256::seed_from_u64(cfg.seed ^ 0x1337_D1E);
            let faults = FaultSchedule::random_kill_restart(
                &mut rng,
                cfg.protocol.n,
                cfg.workload.duration_us,
                4,
            );
            let report = run_with_faults(&cfg, faults);
            assert!(
                report.safety_ok,
                "{variant:?}: divergence under kill/restart (n={}, seed={})",
                cfg.protocol.n, cfg.seed
            );
            assert!(
                report.recovery_ok,
                "{variant:?}: a killed replica's committed prefix was lost \
                 (n={}, seed={}, snap_interval={})",
                cfg.protocol.n, cfg.seed, cfg.protocol.storage.snapshot_interval_entries
            );
        });
    }
}

#[test]
fn v2_and_raft_agree_on_state_machine() {
    // Same workload, same seed: every variant must apply an equivalent
    // committed prefix (commands may differ in count due to scheduling, but
    // each variant's own replicas must agree — checked by safety — and all
    // must have applied a consistent KV view at their own commit point).
    forall("cross-variant state machine agreement", 6, |g| {
        let seed = g.u64_in(0, u64::MAX / 2);
        for variant in Variant::ALL {
            let mut cfg = Config::default();
            cfg.protocol.n = 5;
            cfg.protocol.variant = variant;
            cfg.workload.clients = 4;
            cfg.workload.duration_us = 2_000_000;
            cfg.workload.warmup_us = 200_000;
            cfg.seed = seed;
            let sim = Simulation::new(cfg, FaultSchedule::none(), false);
            let report = sim.run();
            assert!(report.safety_ok);
            assert!(report.completed > 0);
        }
    });
}
