//! Live-cluster integration: the same Node core under real threads, real
//! channels and the real clock. The non-ignored tests are sub-second
//! (tier-1 runs on a single-core CI box); the wall-clock soaks are
//! `#[ignore]`d and run in the dedicated CI `live-smoke` job alongside
//! the TCP-transport soaks (`rust/tests/transport_tcp.rs`).

use epiraft::cluster::run_live;
use epiraft::config::Config;
use epiraft::raft::Variant;

fn cfg(variant: Variant, n: usize) -> Config {
    let mut cfg = Config::default();
    cfg.protocol.n = n;
    cfg.protocol.variant = variant;
    cfg.protocol.round_interval_us = 2_000;
    cfg.workload.clients = 3;
    cfg.workload.duration_us = 1_500_000;
    cfg.workload.warmup_us = 300_000;
    cfg.seed = 99;
    cfg
}

#[test]
#[ignore = "wall-clock soak (~1.5s): runs in the CI live-smoke job"]
fn live_v2_end_to_end() {
    let report = run_live(&cfg(Variant::V2, 5)).expect("live run");
    assert!(report.completed > 20, "completed {}", report.completed);
    assert!(report.logs_consistent);
    // Decentralised commit reached every replica.
    assert!(report.commit_index.iter().all(|&c| c > 0), "{:?}", report.commit_index);
    assert!(report.mean_latency_us > 0.0);
}

#[test]
#[ignore = "wall-clock soak (~3s): runs in the CI live-smoke job"]
fn live_raft_vs_v1_both_serve() {
    let raft = run_live(&cfg(Variant::Raft, 3)).expect("raft");
    let v1 = run_live(&cfg(Variant::V1, 3)).expect("v1");
    for (name, r) in [("raft", &raft), ("v1", &v1)] {
        assert!(r.completed > 20, "{name}: {}", r.completed);
        assert!(r.logs_consistent, "{name}");
    }
}

#[test]
fn live_report_renders() {
    let mut cfg = cfg(Variant::V1, 3);
    cfg.workload.duration_us = 600_000;
    cfg.workload.warmup_us = 100_000;
    let report = run_live(&cfg).expect("run");
    let text = report.render();
    assert!(text.contains("live cluster"));
    assert!(text.contains("replica 0"));
    // The default mpsc transport renders exactly as before the transport
    // layer existed: no transport line, no timeout line when zero.
    assert!(!text.contains("transport:"));
}
