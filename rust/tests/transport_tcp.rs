//! Transport fault tests: the loopback-TCP reconnect path, from the
//! writer's backoff state machine alone up to a full live cluster whose
//! links are hard-closed mid-run.
//!
//! The quick tests run in tier-1; the wall-clock soaks are `#[ignore]`d
//! and run in the CI `live-smoke` job (`cargo test -- --ignored`).

use epiraft::cluster::run_live;
use epiraft::config::Config;
use epiraft::raft::{Message, RequestVoteReply, Variant};
use epiraft::transport::codec;
use epiraft::transport::tcp::{PeerTable, TcpEndpoint};
use std::io::BufReader;
use std::net::TcpListener;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

fn probe(term: u64) -> Message {
    Message::RequestVoteReply(RequestVoteReply { term, from: 0, granted: true })
}

/// Kill an established connection and assert the writer reconnects (with
/// the disconnect reported as peer-down evidence) and traffic flows again
/// on the new connection — no cluster involved, just the transport.
#[test]
fn writer_reconnects_after_connection_drop() {
    let l0 = TcpListener::bind(("127.0.0.1", 0)).expect("bind endpoint listener");
    let l1 = TcpListener::bind(("127.0.0.1", 0)).expect("bind remote listener");
    let table =
        PeerTable::new(vec![l0.local_addr().unwrap(), l1.local_addr().unwrap()]);
    let downs = Arc::new(AtomicU64::new(0));
    let downs_cb = Arc::clone(&downs);
    let ep = TcpEndpoint::start(
        0,
        l0,
        &table,
        64,
        Arc::new(|_msg: Message| {}),
        Arc::new(move |_peer: usize| {
            downs_cb.fetch_add(1, Ordering::Relaxed);
        }),
    )
    .expect("endpoint start");
    let sender = ep.sender(1);

    // First connection: one frame arrives intact.
    sender.send(probe(1));
    let (conn1, _) = l1.accept().expect("first connection");
    let mut r1 = BufReader::new(conn1);
    assert_eq!(codec::read_frame(&mut r1).expect("frame"), Some(probe(1)));

    // Hard-close it; keep sending until the writer notices the corpse,
    // backs off, and reconnects.
    drop(r1);
    l1.set_nonblocking(true).unwrap();
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut term = 2u64;
    let conn2 = loop {
        assert!(Instant::now() < deadline, "writer never reconnected");
        sender.send(probe(term));
        term += 1;
        match l1.accept() {
            Ok((s, _)) => break s,
            Err(_) => thread::sleep(Duration::from_millis(10)),
        }
    };
    conn2.set_nonblocking(false).unwrap();
    // Frames flow again on the new connection (send a few more so the
    // reader has something regardless of what died with connection 1).
    sender.send(probe(1_000));
    let mut r2 = BufReader::new(conn2);
    let msg = codec::read_frame(&mut r2).expect("frame after reconnect");
    assert!(msg.is_some(), "no traffic on the reconnected link");
    assert!(ep.stats().reconnects() >= 1, "reconnect must be counted");
    assert!(
        downs.load(Ordering::Relaxed) >= 1,
        "the dropped connection must be reported as peer-down evidence"
    );
    drop(sender);
    drop(r2);
    ep.shutdown();
}

/// A burst of frames through one writer: whatever coalescing the writer
/// applies, the byte stream must decode back into exactly the frames
/// sent, `frames_out` must count every frame (not every syscall), and the
/// per-peer egress counter must equal the encoded bytes on the wire.
#[test]
fn coalesced_writer_preserves_frames_and_accounts_egress() {
    let l0 = TcpListener::bind(("127.0.0.1", 0)).expect("bind endpoint listener");
    let l1 = TcpListener::bind(("127.0.0.1", 0)).expect("bind remote listener");
    let table =
        PeerTable::new(vec![l0.local_addr().unwrap(), l1.local_addr().unwrap()]);
    let ep = TcpEndpoint::start(
        0,
        l0,
        &table,
        256,
        Arc::new(|_msg: Message| {}),
        Arc::new(|_peer: usize| {}),
    )
    .expect("endpoint start");
    let sender = ep.sender(1);
    const K: u64 = 40;
    for term in 1..=K {
        sender.send(probe(term));
    }
    let (conn, _) = l1.accept().expect("connection");
    let mut r = BufReader::new(conn);
    let mut wire_bytes = 0u64;
    for term in 1..=K {
        let msg = codec::read_frame(&mut r).expect("frame").expect("stream open");
        assert_eq!(msg, probe(term), "frame order/content must survive coalescing");
        let mut buf = Vec::new();
        codec::encode(&msg, &mut buf);
        wire_bytes += buf.len() as u64;
    }
    let deadline = Instant::now() + Duration::from_secs(5);
    while ep.stats().frames_out() < K && Instant::now() < deadline {
        thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(ep.stats().frames_out(), K, "every frame counted");
    assert_eq!(
        ep.stats().egress_bytes_to(1),
        wire_bytes,
        "per-peer egress must equal the encoded bytes"
    );
    assert_eq!(ep.stats().egress_bytes_total(), wire_bytes);
    drop(sender);
    drop(r);
    ep.shutdown();
}

/// Per-peer egress accounting across a reconnect cycle: the counter must
/// keep its pre-drop value (no reset with the connection), keep growing on
/// the new connection, and never exceed one count per frame handed to the
/// sender (no double-count — a frame that died with the old socket is
/// *lost*, not re-counted; Raft's own retransmission path re-sends it as a
/// new frame).
#[test]
fn per_peer_egress_survives_reconnect_without_reset_or_double_count() {
    let l0 = TcpListener::bind(("127.0.0.1", 0)).expect("bind endpoint listener");
    let l1 = TcpListener::bind(("127.0.0.1", 0)).expect("bind remote listener");
    let table = PeerTable::new(vec![l0.local_addr().unwrap(), l1.local_addr().unwrap()]);
    let ep = TcpEndpoint::start(
        0,
        l0,
        &table,
        256,
        Arc::new(|_msg: Message| {}),
        Arc::new(|_peer: usize| {}),
    )
    .expect("endpoint start");
    let sender = ep.sender(1);
    let frame_len = codec::encode_to_vec(&probe(1)).len() as u64;

    // Phase 1: K frames over the first connection, all received.
    const K: u64 = 20;
    for term in 1..=K {
        sender.send(probe(term));
    }
    let (conn1, _) = l1.accept().expect("first connection");
    let mut r1 = BufReader::new(conn1);
    for term in 1..=K {
        assert_eq!(codec::read_frame(&mut r1).expect("frame"), Some(probe(term)));
    }
    let deadline = Instant::now() + Duration::from_secs(5);
    while ep.stats().frames_out() < K && Instant::now() < deadline {
        thread::sleep(Duration::from_millis(5));
    }
    let e1 = ep.stats().egress_bytes_to(1);
    assert_eq!(e1, K * frame_len, "phase-1 egress must equal the bytes on the wire");

    // Kill the connection; keep sending until the writer reconnects,
    // counting every frame handed to the sender.
    drop(r1);
    l1.set_nonblocking(true).unwrap();
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut sends = K;
    let conn2 = loop {
        assert!(Instant::now() < deadline, "writer never reconnected");
        sender.send(probe(500));
        sends += 1;
        match l1.accept() {
            Ok((s, _)) => break s,
            Err(_) => thread::sleep(Duration::from_millis(10)),
        }
    };
    conn2.set_nonblocking(false).unwrap();
    sender.send(probe(1_000));
    sends += 1;
    let mut r2 = BufReader::new(conn2);
    assert!(
        codec::read_frame(&mut r2).expect("frame after reconnect").is_some(),
        "no traffic on the reconnected link"
    );
    assert!(ep.stats().reconnects() >= 1, "reconnect must be counted");

    // No reset: the counter kept its phase-1 value and the frame received
    // on the new connection on top of it. No double-count: at most one
    // count per frame ever handed to the sender (frames the writer dropped
    // with the dead socket, or shed at a full outbox, are not counted).
    let e2 = ep.stats().egress_bytes_to(1);
    assert!(
        e2 >= e1 + frame_len,
        "egress reset across reconnect: {e1} then {e2} (frame {frame_len})"
    );
    assert!(
        e2 <= sends * frame_len,
        "egress double-counted across reconnect: {e2} > {sends} sends x {frame_len}"
    );
    assert_eq!(ep.stats().egress_bytes_total(), e2, "only one peer link exists");
    drop(sender);
    drop(r2);
    ep.shutdown();
}

/// A peer shipping a structurally invalid `EPI_SPARSE` payload (here: a
/// duplicate set-bit index) must cost exactly one `boundary_drops` count
/// and its connection — the endpoint itself keeps serving new
/// connections, and nothing is delivered from the bad frame.
#[test]
fn malformed_sparse_frame_counts_as_boundary_drop() {
    use std::io::Write;
    use std::sync::mpsc;

    let l0 = TcpListener::bind(("127.0.0.1", 0)).expect("bind endpoint listener");
    let l1 = TcpListener::bind(("127.0.0.1", 0)).expect("bind remote listener");
    let addr0 = l0.local_addr().unwrap();
    let table = PeerTable::new(vec![addr0, l1.local_addr().unwrap()]);
    let (tx, rx) = mpsc::channel::<Message>();
    let ep = TcpEndpoint::start(
        0,
        l0,
        &table,
        64,
        Arc::new(move |msg: Message| {
            let _ = tx.send(msg);
        }),
        Arc::new(|_peer: usize| {}),
    )
    .expect("endpoint start");

    // A valid reply frame carrying a forced-sparse epidemic payload, then
    // byte-surgery: duplicate the first set-bit index into the second slot
    // (same surgery `transport_codec.rs` proves decodes as Malformed).
    use epiraft::epidemic::EpidemicPayload;
    use epiraft::raft::AppendEntriesReply;
    let payload = EpidemicPayload::sparse_from_indices(51, 10, 11, vec![3, 10, 40])
        .expect("valid sparse payload");
    let msg = Message::AppendEntriesReply(AppendEntriesReply {
        term: 5,
        from: 1,
        success: true,
        match_hint: 10,
        round: Some(7),
        epidemic: Some(payload),
        seq: 1,
    });
    let mut bad = codec::encode_to_vec(&msg);
    // repr tag offset: frame len(4) + version/kind(2) + term(8) + from(4)
    // + success(1) + match_hint(8) + round presence(1) + round(8) + seq(8);
    // index stream after repr(1) + n(4) + max(8) + next(8) + count(4).
    let repr = 4 + 2 + 8 + 4 + 1 + 8 + 1 + 8 + 8;
    assert_eq!(bad[repr], 2, "sparse repr tag where expected");
    let ix0 = repr + 1 + 4 + 8 + 8 + 4;
    let first: [u8; 4] = bad[ix0..ix0 + 4].try_into().unwrap();
    bad[ix0 + 4..ix0 + 8].copy_from_slice(&first);

    let mut hostile = std::net::TcpStream::connect(addr0).expect("connect to endpoint");
    hostile.write_all(&bad).expect("write malformed frame");
    hostile.flush().unwrap();
    let deadline = Instant::now() + Duration::from_secs(5);
    while ep.stats().boundary_drops() == 0 && Instant::now() < deadline {
        thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(ep.stats().boundary_drops(), 1, "malformed sparse frame must be counted");
    assert_eq!(ep.stats().decode_errors(), 0, "framing itself was fine");
    assert!(rx.try_recv().is_err(), "nothing may be delivered from the bad frame");

    // The endpoint survives: a fresh connection delivers a valid frame.
    let mut ok_conn = std::net::TcpStream::connect(addr0).expect("reconnect to endpoint");
    let good = codec::encode_to_vec(&probe(9));
    ok_conn.write_all(&good).expect("write valid frame");
    ok_conn.flush().unwrap();
    assert_eq!(
        rx.recv_timeout(Duration::from_secs(5)).expect("valid frame delivered"),
        probe(9)
    );
    drop(hostile);
    drop(ok_conn);
    ep.shutdown();
}

fn tcp_cfg(variant: Variant, n: usize, duration_us: u64) -> Config {
    let mut cfg = Config::default();
    cfg.protocol.n = n;
    cfg.protocol.variant = variant;
    cfg.protocol.round_interval_us = 2_000;
    cfg.workload.clients = 2;
    cfg.workload.duration_us = duration_us;
    cfg.workload.warmup_us = duration_us / 5;
    cfg.seed = 11;
    cfg.set("cluster.transport", "tcp").unwrap();
    cfg
}

/// Tier-1 canary for the socket path: a short three-replica cluster over
/// loopback TCP commits and stays consistent.
#[test]
fn tcp_cluster_quick_smoke() {
    let report = run_live(&tcp_cfg(Variant::V2, 3, 700_000)).expect("tcp live run");
    assert!(report.completed > 0, "no requests completed over TCP");
    assert!(report.logs_consistent, "log divergence over TCP");
    assert_eq!(report.transport, "tcp");
    assert!(report.render().contains("transport: tcp"));
    // The per-peer egress counters feed the leader-vs-peer split: the
    // leader replicated entries, the followers at least acked.
    assert!(report.leader_egress_bytes > 0, "leader endpoint wrote no bytes");
    assert!(report.peer_egress_bytes_total > 0, "peer endpoints wrote no bytes");
    assert!(report.render().contains("egress: leader="));
}

/// The ISSUE's fault scenario: kill one replica's connections mid-run;
/// reconnect/backoff must fire, no replica thread may panic (run_live
/// joins them all and would propagate), and committed prefixes must stay
/// consistent.
#[test]
#[ignore = "wall-clock soak (~2s): runs in the CI live-smoke job"]
fn tcp_cluster_survives_link_kill() {
    let mut cfg = tcp_cfg(Variant::V2, 3, 2_000_000);
    cfg.set("cluster.kill_link_node", "1").unwrap();
    cfg.set("cluster.kill_link_at_us", "800000").unwrap();
    let report = run_live(&cfg).expect("tcp live run with link kill");
    assert!(
        report.completed > 20,
        "only {} requests completed across the link kill",
        report.completed
    );
    assert!(report.logs_consistent, "link kill must not diverge committed prefixes");
    assert!(report.reconnects >= 1, "killing live links must trigger reconnects");
    assert!(
        report.commit_index.iter().all(|&c| c > 0),
        "every replica must keep committing: {:?}",
        report.commit_index
    );
}

/// Soak: every variant serves a real workload over loopback TCP.
#[test]
#[ignore = "wall-clock soak (~6s): runs in the CI live-smoke job"]
fn tcp_cluster_serves_all_variants() {
    for variant in Variant::ALL {
        let report = run_live(&tcp_cfg(variant, 5, 1_500_000)).expect("tcp live run");
        assert!(
            report.completed > 20,
            "{variant:?}: only {} requests completed over TCP",
            report.completed
        );
        assert!(report.logs_consistent, "{variant:?}: log divergence over TCP");
        assert!(
            report.commit_index.iter().all(|&c| c > 0),
            "{variant:?}: {:?}",
            report.commit_index
        );
    }
}
