//! Transport fault tests: the loopback-TCP reconnect path, from the
//! writer's backoff state machine alone up to a full live cluster whose
//! links are hard-closed mid-run.
//!
//! The quick tests run in tier-1; the wall-clock soaks are `#[ignore]`d
//! and run in the CI `live-smoke` job (`cargo test -- --ignored`).

use epiraft::cluster::run_live;
use epiraft::config::Config;
use epiraft::raft::{Message, RequestVoteReply, Variant};
use epiraft::transport::codec;
use epiraft::transport::tcp::{PeerTable, TcpEndpoint};
use std::io::BufReader;
use std::net::TcpListener;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

fn probe(term: u64) -> Message {
    Message::RequestVoteReply(RequestVoteReply { term, from: 0, granted: true })
}

/// Kill an established connection and assert the writer reconnects (with
/// the disconnect reported as peer-down evidence) and traffic flows again
/// on the new connection — no cluster involved, just the transport.
#[test]
fn writer_reconnects_after_connection_drop() {
    let l0 = TcpListener::bind(("127.0.0.1", 0)).expect("bind endpoint listener");
    let l1 = TcpListener::bind(("127.0.0.1", 0)).expect("bind remote listener");
    let table =
        PeerTable::new(vec![l0.local_addr().unwrap(), l1.local_addr().unwrap()]);
    let downs = Arc::new(AtomicU64::new(0));
    let downs_cb = Arc::clone(&downs);
    let ep = TcpEndpoint::start(
        0,
        l0,
        &table,
        64,
        Arc::new(|_msg: Message| {}),
        Arc::new(move |_peer: usize| {
            downs_cb.fetch_add(1, Ordering::Relaxed);
        }),
    )
    .expect("endpoint start");
    let sender = ep.sender(1);

    // First connection: one frame arrives intact.
    sender.send(probe(1));
    let (conn1, _) = l1.accept().expect("first connection");
    let mut r1 = BufReader::new(conn1);
    assert_eq!(codec::read_frame(&mut r1).expect("frame"), Some(probe(1)));

    // Hard-close it; keep sending until the writer notices the corpse,
    // backs off, and reconnects.
    drop(r1);
    l1.set_nonblocking(true).unwrap();
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut term = 2u64;
    let conn2 = loop {
        assert!(Instant::now() < deadline, "writer never reconnected");
        sender.send(probe(term));
        term += 1;
        match l1.accept() {
            Ok((s, _)) => break s,
            Err(_) => thread::sleep(Duration::from_millis(10)),
        }
    };
    conn2.set_nonblocking(false).unwrap();
    // Frames flow again on the new connection (send a few more so the
    // reader has something regardless of what died with connection 1).
    sender.send(probe(1_000));
    let mut r2 = BufReader::new(conn2);
    let msg = codec::read_frame(&mut r2).expect("frame after reconnect");
    assert!(msg.is_some(), "no traffic on the reconnected link");
    assert!(ep.stats().reconnects() >= 1, "reconnect must be counted");
    assert!(
        downs.load(Ordering::Relaxed) >= 1,
        "the dropped connection must be reported as peer-down evidence"
    );
    drop(sender);
    drop(r2);
    ep.shutdown();
}

/// A burst of frames through one writer: whatever coalescing the writer
/// applies, the byte stream must decode back into exactly the frames
/// sent, `frames_out` must count every frame (not every syscall), and the
/// per-peer egress counter must equal the encoded bytes on the wire.
#[test]
fn coalesced_writer_preserves_frames_and_accounts_egress() {
    let l0 = TcpListener::bind(("127.0.0.1", 0)).expect("bind endpoint listener");
    let l1 = TcpListener::bind(("127.0.0.1", 0)).expect("bind remote listener");
    let table =
        PeerTable::new(vec![l0.local_addr().unwrap(), l1.local_addr().unwrap()]);
    let ep = TcpEndpoint::start(
        0,
        l0,
        &table,
        256,
        Arc::new(|_msg: Message| {}),
        Arc::new(|_peer: usize| {}),
    )
    .expect("endpoint start");
    let sender = ep.sender(1);
    const K: u64 = 40;
    for term in 1..=K {
        sender.send(probe(term));
    }
    let (conn, _) = l1.accept().expect("connection");
    let mut r = BufReader::new(conn);
    let mut wire_bytes = 0u64;
    for term in 1..=K {
        let msg = codec::read_frame(&mut r).expect("frame").expect("stream open");
        assert_eq!(msg, probe(term), "frame order/content must survive coalescing");
        let mut buf = Vec::new();
        codec::encode(&msg, &mut buf);
        wire_bytes += buf.len() as u64;
    }
    let deadline = Instant::now() + Duration::from_secs(5);
    while ep.stats().frames_out() < K && Instant::now() < deadline {
        thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(ep.stats().frames_out(), K, "every frame counted");
    assert_eq!(
        ep.stats().egress_bytes_to(1),
        wire_bytes,
        "per-peer egress must equal the encoded bytes"
    );
    assert_eq!(ep.stats().egress_bytes_total(), wire_bytes);
    drop(sender);
    drop(r);
    ep.shutdown();
}

fn tcp_cfg(variant: Variant, n: usize, duration_us: u64) -> Config {
    let mut cfg = Config::default();
    cfg.protocol.n = n;
    cfg.protocol.variant = variant;
    cfg.protocol.round_interval_us = 2_000;
    cfg.workload.clients = 2;
    cfg.workload.duration_us = duration_us;
    cfg.workload.warmup_us = duration_us / 5;
    cfg.seed = 11;
    cfg.set("cluster.transport", "tcp").unwrap();
    cfg
}

/// Tier-1 canary for the socket path: a short three-replica cluster over
/// loopback TCP commits and stays consistent.
#[test]
fn tcp_cluster_quick_smoke() {
    let report = run_live(&tcp_cfg(Variant::V2, 3, 700_000)).expect("tcp live run");
    assert!(report.completed > 0, "no requests completed over TCP");
    assert!(report.logs_consistent, "log divergence over TCP");
    assert_eq!(report.transport, "tcp");
    assert!(report.render().contains("transport: tcp"));
    // The per-peer egress counters feed the leader-vs-peer split: the
    // leader replicated entries, the followers at least acked.
    assert!(report.leader_egress_bytes > 0, "leader endpoint wrote no bytes");
    assert!(report.peer_egress_bytes_total > 0, "peer endpoints wrote no bytes");
    assert!(report.render().contains("egress: leader="));
}

/// The ISSUE's fault scenario: kill one replica's connections mid-run;
/// reconnect/backoff must fire, no replica thread may panic (run_live
/// joins them all and would propagate), and committed prefixes must stay
/// consistent.
#[test]
#[ignore = "wall-clock soak (~2s): runs in the CI live-smoke job"]
fn tcp_cluster_survives_link_kill() {
    let mut cfg = tcp_cfg(Variant::V2, 3, 2_000_000);
    cfg.set("cluster.kill_link_node", "1").unwrap();
    cfg.set("cluster.kill_link_at_us", "800000").unwrap();
    let report = run_live(&cfg).expect("tcp live run with link kill");
    assert!(
        report.completed > 20,
        "only {} requests completed across the link kill",
        report.completed
    );
    assert!(report.logs_consistent, "link kill must not diverge committed prefixes");
    assert!(report.reconnects >= 1, "killing live links must trigger reconnects");
    assert!(
        report.commit_index.iter().all(|&c| c > 0),
        "every replica must keep committing: {:?}",
        report.commit_index
    );
}

/// Soak: every variant serves a real workload over loopback TCP.
#[test]
#[ignore = "wall-clock soak (~6s): runs in the CI live-smoke job"]
fn tcp_cluster_serves_all_variants() {
    for variant in Variant::ALL {
        let report = run_live(&tcp_cfg(variant, 5, 1_500_000)).expect("tcp live run");
        assert!(
            report.completed > 20,
            "{variant:?}: only {} requests completed over TCP",
            report.completed
        );
        assert!(report.logs_consistent, "{variant:?}: log divergence over TCP");
        assert!(
            report.commit_index.iter().all(|&c| c > 0),
            "{variant:?}: {:?}",
            report.commit_index
        );
    }
}
