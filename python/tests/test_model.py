"""L2 correctness: quorum_update and cluster_step against the oracle, plus
AOT lowering sanity (HLO text emission — the exact artifact the Rust
runtime loads)."""

import os
import sys

import jax
import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from compile import model
from compile.aot import to_hlo_text
from compile.kernels import ref


def case(seed, b=64, m=16, n_procs=51):
    return ref.random_case(np.random.default_rng(seed), b, m, n_procs)


@pytest.mark.parametrize("seed", range(8))
def test_quorum_update_matches_ref(seed):
    c = case(seed)
    got = model.quorum_update(
        c["bm"], c["mc"], c["nc"], c["me"], c["majority"], c["last_index"], c["last_term_eq"]
    )
    want = ref.quorum_update_ref(
        c["bm"], c["mc"], c["nc"], c["me"], c["majority"], c["last_index"], c["last_term_eq"]
    )
    for g, w, name in zip(got, want, ["bm", "mc", "nc"]):
        np.testing.assert_array_equal(np.asarray(g), w, err_msg=f"{name} (seed={seed})")


@pytest.mark.parametrize("seed", range(4))
def test_cluster_step_matches_ref(seed):
    c = case(seed)
    got = model.cluster_step(
        c["bm"], c["mc"], c["nc"], c["msgs_bm"], c["msgs_mc"], c["msgs_nc"],
        c["count"], c["me"], c["majority"], c["last_index"], c["last_term_eq"],
    )
    want = ref.cluster_step_ref(
        c["bm"], c["mc"], c["nc"], c["msgs_bm"], c["msgs_mc"], c["msgs_nc"],
        c["count"], c["me"], c["majority"], c["last_index"], c["last_term_eq"],
    )
    for g, w, name in zip(got, want, ["bm", "mc", "nc"]):
        np.testing.assert_array_equal(np.asarray(g), w, err_msg=f"{name} (seed={seed})")


def test_majority_fires_update():
    b = 8
    bm = np.zeros((b, ref.W), dtype=np.uint32)
    bm[0, 0] = (1 << 26) - 1  # 26 of 51 = majority
    bm[1, 0] = (1 << 25) - 1  # 25 votes: below majority
    mc = np.zeros(b, dtype=np.uint32)
    nc = np.ones(b, dtype=np.uint32)
    me = np.zeros(b, dtype=np.uint32)
    last_index = np.full(b, 10, dtype=np.uint32)
    last_eq = np.ones(b, dtype=np.uint32)
    got_bm, got_mc, got_nc = model.quorum_update(
        bm, mc, nc, me, np.uint32(26), last_index, last_eq
    )
    got_bm, got_mc, got_nc = map(np.asarray, (got_bm, got_mc, got_nc))
    assert got_mc[0] == 1 and got_nc[0] == 10, "majority row advances"
    assert got_mc[1] == 0 and got_nc[1] == 1, "sub-majority row holds"
    # Own bit re-set on both (last_index >= nc, term eq).
    assert got_bm[0, 0] & 1
    assert got_bm[1, 0] & 1


def test_own_bit_respects_word_boundary():
    b = 2
    bm = np.zeros((b, ref.W), dtype=np.uint32)
    mc = np.zeros(b, dtype=np.uint32)
    nc = np.ones(b, dtype=np.uint32)
    me = np.array([31, 40], dtype=np.uint32)  # word 0 bit 31, word 1 bit 8
    last_index = np.full(b, 5, dtype=np.uint32)
    last_eq = np.ones(b, dtype=np.uint32)
    got_bm, _, _ = model.quorum_update(bm, mc, nc, me, np.uint32(26), last_index, last_eq)
    got_bm = np.asarray(got_bm)
    assert got_bm[0, 0] == 1 << 31 and got_bm[0, 1] == 0
    assert got_bm[1, 0] == 0 and got_bm[1, 1] == 1 << 8


@pytest.mark.parametrize("name", ["merge_fold", "quorum_update", "cluster_step"])
def test_aot_lowering_emits_hlo_text(name):
    shapes = model.example_args(16, 4)
    lowered = jax.jit(model.FUNCTIONS[name]).lower(*shapes[name])
    text = to_hlo_text(lowered)
    assert text.startswith("HloModule"), text[:80]
    assert "u32[" in text
    # Pallas interpret lowering must not leave TPU custom-calls behind.
    assert "tpu_custom_call" not in text
