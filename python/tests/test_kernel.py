"""L1 correctness: the Pallas merge_fold kernel against the numpy oracle.

Exact integer equality is required — the kernel, the oracle and the Rust
native implementation must be bit-identical (the epidemic structures are
protocol state, not floating-point math).
"""

import os
import sys

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from compile.kernels import ref
from compile.kernels.merge import W, merge_fold


def run_kernel(c):
    out = merge_fold(
        c["bm"], c["mc"], c["nc"], c["msgs_bm"], c["msgs_mc"], c["msgs_nc"], c["count"]
    )
    return [np.asarray(x) for x in out]


def run_ref(c):
    return ref.merge_fold_ref(
        c["bm"], c["mc"], c["nc"], c["msgs_bm"], c["msgs_mc"], c["msgs_nc"], c["count"]
    )


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("b,m", [(16, 4), (64, 16), (32, 1)])
def test_merge_fold_matches_ref_random(seed, b, m):
    rng = np.random.default_rng(seed)
    c = ref.random_case(rng, b, m, n_procs=51)
    got = run_kernel(c)
    want = run_ref(c)
    for g, w, name in zip(got, want, ["bm", "mc", "nc"]):
        np.testing.assert_array_equal(g, w, err_msg=f"{name} mismatch (seed={seed})")


def test_zero_count_is_identity():
    rng = np.random.default_rng(1)
    c = ref.random_case(rng, 16, 4, n_procs=51)
    c["count"] = np.zeros(16, dtype=np.uint32)
    bm, mc, nc = run_kernel(c)
    np.testing.assert_array_equal(bm, c["bm"])
    np.testing.assert_array_equal(mc, c["mc"])
    np.testing.assert_array_equal(nc, c["nc"])


def test_invariant_preserved_by_fold():
    # nc > mc on input (random_case guarantees it) must hold on output.
    for seed in range(4):
        c = ref.random_case(np.random.default_rng(seed), 64, 16, n_procs=51)
        _bm, mc, nc = run_kernel(c)
        assert (nc.astype(np.uint64) > mc.astype(np.uint64)).all()


def test_merge_is_idempotent_per_message():
    # Folding the same single message twice == folding it once.
    rng = np.random.default_rng(3)
    c = ref.random_case(rng, 8, 2, n_procs=51)
    c["msgs_bm"][:, 1] = c["msgs_bm"][:, 0]
    c["msgs_mc"][:, 1] = c["msgs_mc"][:, 0]
    c["msgs_nc"][:, 1] = c["msgs_nc"][:, 0]
    once = dict(c)
    once["count"] = np.ones(8, dtype=np.uint32)
    twice = dict(c)
    twice["count"] = np.full(8, 2, dtype=np.uint32)
    for g, w in zip(run_kernel(once), run_kernel(twice)):
        np.testing.assert_array_equal(g, w)


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(0, 999), st.integers(1, 49),
       st.integers(0, 999), st.integers(1, 49), st.data())
def test_single_state_fold_hypothesis(bm0, mc, dn, mc_k, dn_k, data):
    """Hypothesis sweep of the scalar semantics through the kernel."""
    b, m = 8, 2  # kernel geometry stays fixed; lane 0 carries the case
    c = ref.random_case(np.random.default_rng(0), b, m, n_procs=51)
    c["bm"][0] = [bm0 & 0xFFFFFFFF, (bm0 >> 16) & 0x7FFFF]
    c["mc"][0] = mc
    c["nc"][0] = mc + dn
    c["msgs_mc"][0, 0] = mc_k
    c["msgs_nc"][0, 0] = mc_k + dn_k
    c["msgs_bm"][0, 0] = [
        data.draw(st.integers(0, 2**32 - 1)),
        data.draw(st.integers(0, 2**19 - 1)),
    ]
    c["count"][0] = 1
    got = run_kernel(c)
    want = run_ref(c)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g[0], w[0])


def test_kernel_rejects_wrong_word_count():
    rng = np.random.default_rng(5)
    c = ref.random_case(rng, 16, 4, n_procs=51)
    bad = np.zeros((16, W + 1), dtype=np.uint32)
    with pytest.raises(AssertionError):
        merge_fold(bad, c["mc"], c["nc"], c["msgs_bm"], c["msgs_mc"], c["msgs_nc"], c["count"])
