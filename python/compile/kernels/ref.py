"""Pure-numpy oracle for the epidemic-commit kernels.

Scalar, loop-based reimplementation of Algorithms 2 and 3 (§3.2) — the
correctness reference the Pallas kernel and the L2 model are tested
against, and the generator of the golden vectors consumed by the Rust
native≡HLO equivalence tests (``artifacts/golden.json``).
"""

import numpy as np

W = 2  # u32 words per bitmap (matches kernels/merge.py and rust bitset)


def merge_one(bm, mc, nc, bm_k, mc_k, nc_k):
    """Algorithm 3 (Merge) for one state/message pair.

    All args are python ints / length-W lists of ints; returns (bm, mc, nc).
    Must stay bit-identical to ``EpidemicState::merge``.
    """
    bm = list(bm)
    # line 1
    mc = max(mc, mc_k)
    # lines 2-4
    if nc <= nc_k:
        bm = [a | b for a, b in zip(bm, bm_k)]
    # lines 5-7
    if nc <= mc:
        bm = list(bm_k)
        nc = nc_k
    # invariant restore
    if nc <= mc:
        bm = [0] * len(bm)
        nc = (mc + 1) & 0xFFFFFFFF
    return bm, mc, nc


def merge_fold_ref(bm, mc, nc, msgs_bm, msgs_mc, msgs_nc, count):
    """Reference for kernels.merge.merge_fold (numpy arrays in/out)."""
    bm = np.array(bm, dtype=np.uint32).copy()
    mc = np.array(mc, dtype=np.uint32).copy()
    nc = np.array(nc, dtype=np.uint32).copy()
    b, m = np.shape(msgs_mc)
    for i in range(b):
        s_bm = [int(x) for x in bm[i]]
        s_mc, s_nc = int(mc[i]), int(nc[i])
        for k in range(min(int(count[i]), m)):
            s_bm, s_mc, s_nc = merge_one(
                s_bm,
                s_mc,
                s_nc,
                [int(x) for x in msgs_bm[i, k]],
                int(msgs_mc[i, k]),
                int(msgs_nc[i, k]),
            )
        bm[i] = s_bm
        mc[i], nc[i] = s_mc, s_nc
    return bm, mc, nc


def popcount_words(words):
    return sum(bin(int(w)).count("1") for w in words)


def update_step_ref(bm, mc, nc, me, majority, last_index, last_term_eq):
    """One pass of Algorithm 2 + the §3.2 own-bit rule, for one state.

    Must stay bit-identical to ``EpidemicState::update_step``.
    Returns (bm, mc, nc).
    """
    bm = list(bm)
    fired = popcount_words(bm) >= majority
    if fired:
        mc = nc  # line 2
        bm = [0] * len(bm)  # line 3
        if nc >= last_index or not last_term_eq:  # line 4
            nc = (nc + 1) & 0xFFFFFFFF  # line 5
        else:
            nc = last_index  # line 7
    # own-bit rule (line 8 generalised per the prose)
    if last_index >= nc and last_term_eq:
        bm[me // 32] |= 1 << (me % 32)
    return bm, mc, nc


def quorum_update_ref(bm, mc, nc, me, majority, last_index, last_term_eq):
    """Reference for model.quorum_update (batched over axis 0)."""
    bm = np.array(bm, dtype=np.uint32).copy()
    mc = np.array(mc, dtype=np.uint32).copy()
    nc = np.array(nc, dtype=np.uint32).copy()
    b = bm.shape[0]
    for i in range(b):
        s_bm, s_mc, s_nc = update_step_ref(
            [int(x) for x in bm[i]],
            int(mc[i]),
            int(nc[i]),
            int(me[i]),
            int(majority),
            int(last_index[i]),
            bool(last_term_eq[i]),
        )
        bm[i] = s_bm
        mc[i], nc[i] = s_mc, s_nc
    return bm, mc, nc


def cluster_step_ref(
    bm, mc, nc, msgs_bm, msgs_mc, msgs_nc, count, me, majority, last_index, last_term_eq
):
    """Reference for model.cluster_step: merge fold then one update pass."""
    bm, mc, nc = merge_fold_ref(bm, mc, nc, msgs_bm, msgs_mc, msgs_nc, count)
    return quorum_update_ref(bm, mc, nc, me, majority, last_index, last_term_eq)


def random_case(rng, b, m, n_procs):
    """Draw a random but *plausible* batch (invariant nc > mc holds on
    inputs, bitmaps only use the low n_procs bits)."""

    def bitmaps(shape):
        full = rng.integers(0, 2**32, size=shape + (W,), dtype=np.uint64)
        mask = np.zeros(W, dtype=np.uint64)
        for i in range(n_procs):
            mask[i // 32] |= np.uint64(1 << (i % 32))
        return (full & mask).astype(np.uint32)

    mc = rng.integers(0, 1000, size=(b,)).astype(np.uint32)
    nc = (mc + rng.integers(1, 50, size=(b,)).astype(np.uint32)).astype(np.uint32)
    msgs_mc = rng.integers(0, 1000, size=(b, m)).astype(np.uint32)
    msgs_nc = (msgs_mc + rng.integers(1, 50, size=(b, m)).astype(np.uint32)).astype(np.uint32)
    return dict(
        bm=bitmaps((b,)),
        mc=mc,
        nc=nc,
        msgs_bm=bitmaps((b, m)),
        msgs_mc=msgs_mc,
        msgs_nc=msgs_nc,
        count=rng.integers(0, m + 1, size=(b,)).astype(np.uint32),
        me=rng.integers(0, n_procs, size=(b,)).astype(np.uint32),
        majority=np.uint32(n_procs // 2 + 1),
        last_index=rng.integers(0, 1100, size=(b,)).astype(np.uint32),
        last_term_eq=rng.integers(0, 2, size=(b,)).astype(np.uint32),
    )
