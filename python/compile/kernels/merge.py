"""Layer 1 — Pallas kernel: batched epidemic-commit Merge fold.

The V2 hot-spot: every replica folds batches of received
``(bitmap, max_commit, next_commit)`` triples (Algorithm 3 of the paper)
into its local state. The kernel processes B independent replica states,
each folding M messages, in one launch — the vectorised "fleet step" the
Rust runtime calls through PJRT for batched simulation and for the
`micro_hotpath` benchmark.

Semantics (must stay bit-identical to ``EpidemicState::merge`` in
``rust/src/epidemic/commit.rs``; DESIGN.md §4.1 documents the `<=`
resolution of the paper's pseudocode/prose mismatch):

    for each message k < count:
        mc  = max(mc, mc_k)                     # Alg. 3 line 1
        if nc <= nc_k:  bm |= bm_k              # lines 2-4
        if nc <= mc:    bm, nc = bm_k, nc_k     # lines 5-7
        if nc <= mc:    bm, nc = 0,   mc + 1    # invariant restore

Layout: bitmaps are W=2 little-endian u32 words (up to 64 replicas) —
the same layout as ``util::bitset::Bitmap`` on the Rust side.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the fold is element-wise
over ``(B, W)`` u32 lanes — VPU work tiled by BlockSpec over the B axis so
each (B_TILE, M, W) message block sits in VMEM; there is no matmul, so the
MXU is idle and the roofline is memory-bound. ``interpret=True`` everywhere
on CPU (Mosaic custom-calls cannot run on the CPU PJRT plugin).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Bitmap words per state: W*32 >= max cluster size (paper: 51 replicas).
W = 2
# Default batch geometry for the AOT artifacts.
DEFAULT_B = 64
DEFAULT_M = 16
# Block tile over the replica axis.
B_TILE = 16


def _merge_fold_kernel(
    bm_ref,
    mc_ref,
    nc_ref,
    msgs_bm_ref,
    msgs_mc_ref,
    msgs_nc_ref,
    count_ref,
    out_bm_ref,
    out_mc_ref,
    out_nc_ref,
):
    """Pallas kernel body: fold M messages into each of the block's states."""
    bm = bm_ref[...]  # (BT, W) u32
    mc = mc_ref[...]  # (BT,)  u32
    nc = nc_ref[...]  # (BT,)  u32
    count = count_ref[...]  # (BT,) u32
    m = msgs_mc_ref.shape[1]

    def body(k, carry):
        bm, mc, nc = carry
        valid = k < count  # (BT,) bool
        bm_k = msgs_bm_ref[:, k, :]
        mc_k = msgs_mc_ref[:, k]
        nc_k = msgs_nc_ref[:, k]
        # line 1
        mc2 = jnp.maximum(mc, mc_k)
        # lines 2-4 (votes for >= index certify ours)
        or_ok = nc <= nc_k
        bm2 = jnp.where(or_ok[:, None], bm | bm_k, bm)
        # lines 5-7 (local vote already majority-confirmed: adopt received)
        adopt = nc <= mc2
        bm3 = jnp.where(adopt[:, None], bm_k, bm2)
        nc2 = jnp.where(adopt, nc_k, nc)
        # invariant restore (stale received state)
        stale = nc2 <= mc2
        bm4 = jnp.where(stale[:, None], jnp.zeros_like(bm3), bm3)
        nc3 = jnp.where(stale, mc2 + jnp.uint32(1), nc2)
        # masked lanes keep their previous state
        bm5 = jnp.where(valid[:, None], bm4, bm)
        mc3 = jnp.where(valid, mc2, mc)
        nc4 = jnp.where(valid, nc3, nc)
        return bm5, mc3, nc4

    bm, mc, nc = jax.lax.fori_loop(0, m, body, (bm, mc, nc))
    out_bm_ref[...] = bm
    out_mc_ref[...] = mc
    out_nc_ref[...] = nc


@functools.partial(jax.jit, static_argnames=())
def merge_fold(bm, mc, nc, msgs_bm, msgs_mc, msgs_nc, count):
    """Fold message batches into states.

    Args:
      bm:      (B, W)    u32 — local bitmaps.
      mc:      (B,)      u32 — local max_commit.
      nc:      (B,)      u32 — local next_commit.
      msgs_bm: (B, M, W) u32 — received bitmaps.
      msgs_mc: (B, M)    u32 — received max_commit.
      msgs_nc: (B, M)    u32 — received next_commit.
      count:   (B,)      u32 — number of valid messages per state.

    Returns: (bm', mc', nc') with the same shapes/dtypes as the inputs.
    """
    b, w = bm.shape
    _, m = msgs_mc.shape
    assert w == W, f"bitmap must have {W} words"
    bt = B_TILE if b % B_TILE == 0 else b
    grid = (b // bt,)
    return pl.pallas_call(
        _merge_fold_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, w), lambda i: (i, 0)),
            pl.BlockSpec((bt,), lambda i: (i,)),
            pl.BlockSpec((bt,), lambda i: (i,)),
            pl.BlockSpec((bt, m, w), lambda i: (i, 0, 0)),
            pl.BlockSpec((bt, m), lambda i: (i, 0)),
            pl.BlockSpec((bt, m), lambda i: (i, 0)),
            pl.BlockSpec((bt,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bt, w), lambda i: (i, 0)),
            pl.BlockSpec((bt,), lambda i: (i,)),
            pl.BlockSpec((bt,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, w), jnp.uint32),
            jax.ShapeDtypeStruct((b,), jnp.uint32),
            jax.ShapeDtypeStruct((b,), jnp.uint32),
        ],
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(bm, mc, nc, msgs_bm, msgs_mc, msgs_nc, count)
