"""Layer 2 — JAX compute graph over the L1 Pallas kernel.

* ``quorum_update`` — vectorised single pass of Algorithm 2 (Update) plus
  the §3.2 own-bit rule (popcount majority test, commit advance, bitmap
  reset, own-bit set).
* ``cluster_step`` — the fleet step: fold received message batches into B
  replica states (L1 kernel) and run one Update pass on each.

Both are lowered once by ``aot.py`` to HLO text and executed from the Rust
runtime through PJRT; python never runs at request time.
"""

import jax
import jax.numpy as jnp

from .kernels.merge import W, merge_fold


def quorum_update(bm, mc, nc, me, majority, last_index, last_term_eq):
    """One pass of Algorithm 2 + own-bit rule, batched over axis 0.

    Args:
      bm:          (B, W) u32 bitmaps.
      mc, nc:      (B,)   u32 max_commit / next_commit.
      me:          (B,)   u32 own process id per state.
      majority:    ()     u32 majority threshold (⌊n/2⌋+1).
      last_index:  (B,)   u32 index of last log entry.
      last_term_eq:(B,)   u32 1 iff term(last entry) == current term.

    Returns (bm', mc', nc').
    """
    last_eq = last_term_eq != 0
    votes = jax.lax.population_count(bm).sum(axis=1, dtype=jnp.uint32)
    fired = votes >= majority
    # lines 2-3
    mc2 = jnp.where(fired, nc, mc)
    bm2 = jnp.where(fired[:, None], jnp.zeros_like(bm), bm)
    # lines 4-7
    incr = (nc >= last_index) | (~last_eq)
    nc2 = jnp.where(fired, jnp.where(incr, nc + jnp.uint32(1), last_index), nc)
    # own-bit rule (line 8 generalised)
    own = (last_index >= nc2) & last_eq
    words = jnp.arange(W, dtype=jnp.uint32)[None, :]  # (1, W)
    one_hot = jnp.where(
        (me[:, None] // jnp.uint32(32)) == words,
        jnp.left_shift(jnp.uint32(1), me[:, None] % jnp.uint32(32)),
        jnp.uint32(0),
    )
    bm3 = jnp.where(own[:, None], bm2 | one_hot, bm2)
    return bm3, mc2, nc2


def cluster_step(
    bm, mc, nc, msgs_bm, msgs_mc, msgs_nc, count, me, majority, last_index, last_term_eq
):
    """Fleet step: merge the message batch (L1 kernel), then Update."""
    bm, mc, nc = merge_fold(bm, mc, nc, msgs_bm, msgs_mc, msgs_nc, count)
    return quorum_update(bm, mc, nc, me, majority, last_index, last_term_eq)


def example_args(b, m):
    """ShapeDtypeStructs for AOT lowering at batch geometry (b, m)."""
    u32 = jnp.uint32
    return dict(
        merge_fold=(
            jax.ShapeDtypeStruct((b, W), u32),
            jax.ShapeDtypeStruct((b,), u32),
            jax.ShapeDtypeStruct((b,), u32),
            jax.ShapeDtypeStruct((b, m, W), u32),
            jax.ShapeDtypeStruct((b, m), u32),
            jax.ShapeDtypeStruct((b, m), u32),
            jax.ShapeDtypeStruct((b,), u32),
        ),
        quorum_update=(
            jax.ShapeDtypeStruct((b, W), u32),
            jax.ShapeDtypeStruct((b,), u32),
            jax.ShapeDtypeStruct((b,), u32),
            jax.ShapeDtypeStruct((b,), u32),
            jax.ShapeDtypeStruct((), u32),
            jax.ShapeDtypeStruct((b,), u32),
            jax.ShapeDtypeStruct((b,), u32),
        ),
        cluster_step=(
            jax.ShapeDtypeStruct((b, W), u32),
            jax.ShapeDtypeStruct((b,), u32),
            jax.ShapeDtypeStruct((b,), u32),
            jax.ShapeDtypeStruct((b, m, W), u32),
            jax.ShapeDtypeStruct((b, m), u32),
            jax.ShapeDtypeStruct((b, m), u32),
            jax.ShapeDtypeStruct((b,), u32),
            jax.ShapeDtypeStruct((b,), u32),
            jax.ShapeDtypeStruct((), u32),
            jax.ShapeDtypeStruct((b,), u32),
            jax.ShapeDtypeStruct((b,), u32),
        ),
    )


FUNCTIONS = {
    "merge_fold": merge_fold,
    "quorum_update": quorum_update,
    "cluster_step": cluster_step,
}
