"""AOT lowering: jit → StableHLO → XlaComputation → **HLO text**.

HLO *text* (not ``.serialize()``) is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(what the published ``xla`` 0.1.6 rust crate links) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md and DESIGN.md §3.

Outputs (``make artifacts``):
  artifacts/merge_fold.hlo.txt     — L1 Pallas kernel (interpret lowering)
  artifacts/quorum_update.hlo.txt  — L2 Update pass
  artifacts/cluster_step.hlo.txt   — merge ∘ update fleet step
  artifacts/meta.json              — batch geometry for the Rust loader
  artifacts/golden.json            — ref-computed vectors for
                                     native ≡ HLO equivalence tests
"""

import argparse
import json
import os
import sys

import jax
import numpy as np
from jax._src.lib import xla_client as xc

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from compile import model  # noqa: E402
from compile.kernels import ref  # noqa: E402


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def golden_cases(b, m, n_cases=4, n_procs=51, seed=20230713):
    """Random input/output vectors computed with the numpy oracle."""
    rng = np.random.default_rng(seed)
    cases = []
    for _ in range(n_cases):
        c = ref.random_case(rng, b, m, n_procs)
        out_bm, out_mc, out_nc = ref.cluster_step_ref(
            c["bm"], c["mc"], c["nc"], c["msgs_bm"], c["msgs_mc"], c["msgs_nc"],
            c["count"], c["me"], c["majority"], c["last_index"], c["last_term_eq"],
        )
        mf_bm, mf_mc, mf_nc = ref.merge_fold_ref(
            c["bm"], c["mc"], c["nc"], c["msgs_bm"], c["msgs_mc"], c["msgs_nc"], c["count"]
        )
        cases.append(
            {
                "in": {k: np.asarray(v).flatten().tolist() for k, v in c.items()},
                "merge_fold_out": {
                    "bm": mf_bm.flatten().tolist(),
                    "mc": mf_mc.flatten().tolist(),
                    "nc": mf_nc.flatten().tolist(),
                },
                "cluster_step_out": {
                    "bm": out_bm.flatten().tolist(),
                    "mc": out_mc.flatten().tolist(),
                    "nc": out_nc.flatten().tolist(),
                },
            }
        )
    return cases


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=None, help="artifacts directory")
    ap.add_argument("--out", default=None, help="(legacy) single-artifact path; sets out-dir")
    ap.add_argument("--batch", type=int, default=None, help="B (replica batch)")
    ap.add_argument("--msgs", type=int, default=None, help="M (messages per state)")
    args = ap.parse_args()

    from compile.kernels.merge import DEFAULT_B, DEFAULT_M

    b = args.batch or DEFAULT_B
    m = args.msgs or DEFAULT_M

    out_dir = args.out_dir
    if out_dir is None and args.out is not None:
        out_dir = os.path.dirname(args.out) or "."
    if out_dir is None:
        out_dir = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "..", "artifacts")
    out_dir = os.path.abspath(out_dir)
    os.makedirs(out_dir, exist_ok=True)

    shapes = model.example_args(b, m)
    written = []
    for name, fn in model.FUNCTIONS.items():
        lowered = jax.jit(fn).lower(*shapes[name])
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        written.append((name, path, len(text)))

    meta = {"B": b, "M": m, "W": ref.W, "version": 1, "functions": list(model.FUNCTIONS)}
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)

    golden = {"B": b, "M": m, "W": ref.W, "cases": golden_cases(b, m)}
    with open(os.path.join(out_dir, "golden.json"), "w") as f:
        json.dump(golden, f)

    for name, path, size in written:
        print(f"wrote {path} ({size} chars)")
    print(f"wrote {out_dir}/meta.json and golden.json (B={b}, M={m}, W={ref.W})")


if __name__ == "__main__":
    main()
